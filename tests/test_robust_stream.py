"""Streamed robust aggregation (PR 7): the order-statistic reducers
(TrimmedMean / CoordMedian) stream off the store through the per-
coordinate top-k/bottom-k carve, matching the dense oracles:

  * carve stream == dense sort at chunk 1 / odd / pow2 and ragged final
    blocks, both engine strategies and the distributed mesh;
  * mixed compressed + dense rounds fold through the same carve (the
    dequant runs in-trace, so the order statistics match a host-side
    dequant exactly);
  * the TrimmedMean over-trim NaN regression (2*int(n*beta) >= n) is
    clamped to (n-1)//2;
  * Zeno's validation gradient is per-call state, safe across two
    concurrent tenants;
  * the service's state budget routes huge carve rounds dense with a
    RoundReport note (covered in test_streaming / test_async_rounds).
"""
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AggregationService, LocalEngine, UpdateStore
from repro.core.fusion import get_fusion
from repro.core.fusion.robust import CoordMedian, TrimmedMean, Zeno
from repro.kernels.robust_fusion.ops import carve_stream_dense
from repro.kernels.robust_fusion.ref import coordmedian_ref, trimmedmean_ref

RNG = np.random.default_rng(7)


def _blocks(u, w, chunk):
    for lo in range(0, u.shape[0], chunk):
        yield u[lo:lo + chunk], w[lo:lo + chunk]


def _oracle(fusion, u):
    if fusion.name == "coordmedian":
        return np.asarray(coordmedian_ref(jnp.asarray(u)))
    return np.asarray(
        trimmedmean_ref(jnp.asarray(u), fusion.trim_count(u.shape[0]))
    )


# -- streamed carve == dense oracle -------------------------------------------


@pytest.mark.parametrize("fusion", [CoordMedian(), TrimmedMean(beta=0.2)])
@pytest.mark.parametrize("strategy", ["jnp", "pallas"])
@pytest.mark.parametrize("n,p,chunk", [
    (9, 257, 1),     # chunk 1: every row is its own fold
    (13, 301, 3),    # odd chunk, ragged final block
    (16, 64, 8),     # pow2, exact blocks
])
def test_carve_stream_matches_dense(fusion, strategy, n, p, chunk):
    u = RNG.normal(size=(n, p)).astype(np.float32)
    w = np.ones((n,), np.float32)
    eng = LocalEngine(strategy=strategy)
    streamed, rep = eng.fuse_stream(
        fusion, _blocks(u, w, chunk), chunk_rows=chunk, n_hint=n
    )
    np.testing.assert_allclose(
        np.asarray(streamed), _oracle(fusion, u), rtol=1e-5, atol=1e-5
    )
    assert rep.n_rows == n
    assert rep.acc_state is not None and len(rep.acc_state) == 4


def test_carve_stream_dense_harness_matches_refs():
    u = jnp.asarray(RNG.normal(size=(11, 130)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(carve_stream_dense(u, 2, chunk=3)),
        np.asarray(trimmedmean_ref(u, 2)), rtol=1e-5, atol=1e-5,
    )
    np.testing.assert_allclose(
        np.asarray(carve_stream_dense(u, 5, chunk=4)),  # (11-1)//2: median
        np.asarray(coordmedian_ref(u)), rtol=1e-5, atol=1e-5,
    )


def test_carve_stream_ignores_client_weights():
    """Order statistics are unweighted: arbitrary store weights must not
    change the fold (the engine only uses row validity)."""
    n, p = 10, 65
    u = RNG.normal(size=(n, p)).astype(np.float32)
    w = RNG.uniform(0.1, 9.0, size=(n,)).astype(np.float32)
    fused, _ = LocalEngine().fuse_stream(
        CoordMedian(), _blocks(u, w, 4), chunk_rows=4, n_hint=n
    )
    np.testing.assert_allclose(
        np.asarray(fused), np.median(u, axis=0), rtol=1e-5, atol=1e-5
    )


def test_carve_stream_rejects_staleness_scale():
    n, p = 6, 16
    u = RNG.normal(size=(n, p)).astype(np.float32)
    w = np.ones((n,), np.float32)

    def blocks():
        yield u[:3], w[:3], np.full((3,), 0.5, np.float32)
        yield u[3:], w[3:], np.full((3,), 0.5, np.float32)

    with pytest.raises(ValueError, match="staleness"):
        LocalEngine().fuse_stream(TrimmedMean(), blocks(), chunk_rows=3,
                                  n_hint=n)


def test_service_streamed_trimmedmean_sync_and_async():
    """The acceptance path: AggregationService(fusion=TrimmedMean)
    streams a store round — sync and async — to the dense oracle."""
    n, p = 12, 512
    u = RNG.normal(size=(n, p)).astype(np.float32)
    fusion = TrimmedMean(beta=0.2)
    oracle = _oracle(fusion, u)
    for async_round in (False, True):
        store = UpdateStore()
        for i in range(n):
            store.write(f"c{i}", u[i])
        svc = AggregationService(fusion=TrimmedMean(beta=0.2), store=store,
                                 monitor_timeout=1.0,
                                 stream_chunk_bytes=4 * p * 5)
        fused, rep = svc.aggregate(from_store=True, expected_clients=n,
                                   async_round=async_round)
        assert rep.streamed
        assert rep.async_round == async_round
        np.testing.assert_allclose(np.asarray(fused), oracle,
                                   rtol=1e-5, atol=1e-5)


def test_service_streamed_carve_reuses_warm_step():
    """A second same-shape round must reuse the carve step executable."""
    from repro.utils import jitcache

    n, p = 8, 128
    store = UpdateStore()
    svc = AggregationService(fusion=TrimmedMean(beta=0.2), store=store,
                             monitor_timeout=0.5,
                             stream_chunk_bytes=4 * p * 3)
    for rnd in range(2):
        u = RNG.normal(size=(n, p)).astype(np.float32)
        for i in range(n):
            store.write(f"c{i}", u[i])
        if rnd == 1:
            before = jitcache.trace_count()
        fused, rep = svc.aggregate(from_store=True, expected_clients=n)
        assert rep.streamed
        np.testing.assert_allclose(
            np.asarray(fused),
            _oracle(TrimmedMean(beta=0.2), u), rtol=1e-5, atol=1e-5,
        )
        store.clear()
    assert jitcache.trace_count() == before, "warm carve round re-traced"
    assert rep.phase_seconds["compile"] == 0.0


def test_service_mixed_compressed_dense_carve_round():
    """Stragglers may write uncompressed fp32 into a compressed round;
    the carve folds both payload kinds. Oracle: host-side dequant of the
    compressed rows (in-trace dequant is bit-identical)."""
    n, p = 10, 200
    u = RNG.normal(size=(n, p)).astype(np.float32)
    store = UpdateStore()
    svc = AggregationService(fusion=TrimmedMean(beta=0.2), store=store,
                             monitor_timeout=0.5, compress=True)
    mixed = np.empty_like(u)
    for i in range(n):
        if i % 3 == 0:   # straggler: dense fp32
            store.write(f"c{i}", u[i])
            mixed[i] = u[i]
        else:
            cu = svc.compress_update(f"c{i}", u[i])
            store.write(f"c{i}", cu)
            mixed[i] = cu.dequantize()[:p]
    fused, rep = svc.aggregate(from_store=True, expected_clients=n)
    assert rep.streamed
    np.testing.assert_allclose(
        np.asarray(fused), _oracle(TrimmedMean(beta=0.2), mixed),
        rtol=1e-5, atol=1e-5,
    )


# -- TrimmedMean over-trim regression (satellite a) ---------------------------


@pytest.mark.parametrize("n,beta", [(4, 0.5), (5, 0.5), (3, 0.4), (2, 0.5)])
def test_trimmedmean_over_trim_clamps_instead_of_nan(n, beta):
    """2*int(n*beta) >= n used to divide by zero (NaN fused model); the
    trim count now clamps to (n-1)//2."""
    u = RNG.normal(size=(n, 33)).astype(np.float32)
    f = TrimmedMean(beta=beta)
    k = f.trim_count(n)
    assert 2 * k < n
    dense = np.asarray(f.fuse(jnp.asarray(u), jnp.ones((n,))))
    assert np.isfinite(dense).all()
    np.testing.assert_allclose(
        dense, np.asarray(trimmedmean_ref(jnp.asarray(u), k)),
        rtol=1e-5, atol=1e-6,
    )
    streamed, _ = LocalEngine().fuse_stream(
        f, _blocks(u, np.ones((n,), np.float32), 2), chunk_rows=2, n_hint=n
    )
    np.testing.assert_allclose(np.asarray(streamed), dense,
                               rtol=1e-5, atol=1e-5)


# -- Zeno per-call validation gradient (satellite b) --------------------------


def test_zeno_val_grad_is_per_call_state():
    """Two tenants scoring against DIFFERENT validation gradients on one
    shared service must not race one fusion's _g_val."""
    n, p = 6, 64
    u = RNG.normal(size=(n, p)).astype(np.float32)
    g1 = np.ones((p,), np.float32)
    g2 = -np.ones((p,), np.float32)
    base = Zeno()
    ref1 = np.asarray(base.with_val_grad(g1).fuse(jnp.asarray(u),
                                                  jnp.ones((n,))))
    ref2 = np.asarray(base.with_val_grad(g2).fuse(jnp.asarray(u),
                                                  jnp.ones((n,))))
    assert base._g_val is None   # clone, not mutation
    assert not np.allclose(ref1, ref2)

    svc = AggregationService(fusion="zeno")
    results = {}
    errors = []

    def round_for(tenant, g, ref):
        try:
            fused, _ = svc.aggregate(updates=[r for r in u], val_grad=g,
                                     tenant=tenant)
            results[tenant] = (np.asarray(fused), ref)
        except Exception as exc:  # pragma: no cover - surfaced below
            errors.append(exc)

    ts = [threading.Thread(target=round_for, args=("a", g1, ref1)),
          threading.Thread(target=round_for, args=("b", g2, ref2))]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errors, errors
    for tenant, (fused, ref) in results.items():
        np.testing.assert_allclose(fused, ref, rtol=1e-5, atol=1e-6,
                                   err_msg=f"tenant {tenant}")
    assert svc.fusion._g_val is None


def test_zeno_set_val_grad_still_works():
    """The legacy mutating setter stays for single-tenant callers."""
    n, p = 5, 32
    u = jnp.asarray(RNG.normal(size=(n, p)).astype(np.float32))
    g = jnp.ones((p,))
    f = Zeno()
    f.set_val_grad(g)
    np.testing.assert_allclose(
        np.asarray(f.fuse(u, jnp.ones((n,)))),
        np.asarray(Zeno().with_val_grad(g).fuse(u, jnp.ones((n,)))),
        rtol=1e-6, atol=1e-7,
    )


# -- carve state carry across streams -----------------------------------------


def test_carve_acc_state_resumes_stream():
    """acc_state from a closed stream seeds a second stream; the result
    equals one pass over the concatenated rows (async carry-over)."""
    n1, n2, p = 6, 5, 90
    u1 = RNG.normal(size=(n1, p)).astype(np.float32)
    u2 = RNG.normal(size=(n2, p)).astype(np.float32)
    n = n1 + n2
    f = CoordMedian()
    eng = LocalEngine()
    _, rep1 = eng.fuse_stream(
        f, _blocks(u1, np.ones((n1,), np.float32), 3),
        chunk_rows=3, n_hint=n,
    )
    fused, rep2 = eng.fuse_stream(
        f, _blocks(u2, np.ones((n2,), np.float32), 3),
        init=rep1.acc_state, chunk_rows=3, n_hint=n,
    )
    np.testing.assert_allclose(
        np.asarray(fused), np.median(np.vstack([u1, u2]), axis=0),
        rtol=1e-5, atol=1e-5,
    )
    assert rep2.n_rows == n2


def test_carve_rejects_staleness_discount_service():
    with pytest.raises(ValueError, match="weighted"):
        AggregationService(fusion="trimmedmean", staleness_discount=0.9)


def test_coordmedian_large_n_state_signature_scales():
    """K grows with n for the median: the state signature (and so the
    compile-cache key) must depend on n_hint."""
    f = CoordMedian()
    assert f.state_signature(100, 5) != f.state_signature(100, 50)
    assert f.state_nbytes(100, 51) > f.state_nbytes(100, 5)
    with pytest.raises(ValueError, match="n_hint"):
        f.init_state(100, None)
