"""Adaptive aggregation controller (repro/core/adaptive.py) and its
threading through store/monitor/service:

  * ArrivalModel — EW empirical quantile learning, censoring of
    fractions that never arrive, drop-out decay of the attainable
    fraction;
  * AdaptiveController — static gate until warmup, learned
    threshold/deadline after, cost_bias extremes, timeout cap, restart
    persistence via state_dict;
  * Planner.round_objective — the cost-vs-staleness knob's monotonicity;
  * Monitor — pluggable close policy;
  * AggregationService — learned gate closes a drop-out round early
    (the paper's adaptive claim, scripted clock), per-tenant carry
    isolation;
  * UpdateStore — arrival timestamps, event-driven arrival wakeup, and
    SpoolTailer ingestion of externally written spool blobs.
"""
import bisect
import math
import os
import threading
import time

import numpy as np
import pytest

from repro.core import (
    AdaptiveController,
    AggregationService,
    ArrivalModel,
    ClosePolicy,
    Monitor,
    Planner,
    SpoolTailer,
    UpdateStore,
)

RNG = np.random.default_rng(77)


class ScriptedClock:
    def __init__(self):
        self.t = 0.0
        self._events = []

    def at(self, t, fn):
        bisect.insort(self._events, (t, id(fn), fn))

    def clock(self):
        return self.t

    def sleep(self, seconds):
        self.t += seconds
        while self._events and self._events[0][0] <= self.t:
            _, _, fn = self._events.pop(0)
            fn()


def _mk(n, p=48):
    u = RNG.normal(size=(n, p)).astype(np.float32)
    w = RNG.uniform(1, 5, size=(n,)).astype(np.float32)
    return u, w


def _fedavg(u, w):
    return np.einsum("np,n->p", u, w) / (w.sum() + 1e-6)


# -- ArrivalModel --------------------------------------------------------------


def test_arrival_model_learns_uniform_quantiles():
    m = ArrivalModel(n_quantiles=10, ema=0.5)
    offsets = np.linspace(0.1, 1.0, 10)   # client k arrives at 0.1*(k+1)
    for _ in range(4):
        m.observe(offsets, expected=10)
    assert m.rounds == 4
    assert m.attainable == pytest.approx(1.0)
    assert m.wait_for(0.5) == pytest.approx(0.5, abs=0.05)
    assert m.wait_for(1.0) == pytest.approx(1.0, abs=0.05)


def test_arrival_model_censors_missing_fractions():
    """Only 5 of 10 ever arrive: fractions past 0.5 stay unknown (inf)
    and the attainable fraction converges to 0.5."""
    m = ArrivalModel(n_quantiles=10, ema=0.5)
    for _ in range(5):
        m.observe(np.linspace(0.1, 0.5, 5), expected=10)
    assert m.wait_for(0.5) == pytest.approx(0.5, abs=0.05)
    assert math.isinf(m.wait_for(0.9))
    assert m.attainable == pytest.approx(0.5, abs=0.02)


def test_arrival_model_ema_tracks_shift():
    """The curve follows a regime change within a few rounds (EW, not
    all-history average)."""
    m = ArrivalModel(n_quantiles=10, ema=0.5)
    for _ in range(3):
        m.observe(np.linspace(0.2, 2.0, 10), expected=10)   # slow fleet
    slow = m.wait_for(1.0)
    for _ in range(4):
        m.observe(np.linspace(0.02, 0.2, 10), expected=10)  # fast fleet
    fast = m.wait_for(1.0)
    assert fast < slow / 3


def test_arrival_model_state_dict_roundtrip():
    m = ArrivalModel(n_quantiles=8, ema=0.4)
    m.observe(np.linspace(0.1, 0.4, 4), expected=8)
    m2 = ArrivalModel.from_state_dict(m.state_dict())
    assert m2.rounds == m.rounds
    assert m2.attainable == pytest.approx(m.attainable)
    assert m2.wait_for(0.5) == pytest.approx(m.wait_for(0.5))
    assert math.isinf(m2.wait_for(1.0)) == math.isinf(m.wait_for(1.0))


# -- AdaptiveController --------------------------------------------------------


def _trained(cost_bias, offsets, expected, rounds=3, timeout=30.0):
    c = AdaptiveController(cost_bias=cost_bias, threshold_frac=0.8,
                           timeout=timeout)
    for _ in range(rounds):
        c.observe_round("m", offsets, expected, est_seconds=0.01)
    return c


def test_controller_static_until_warmup():
    c = AdaptiveController(threshold_frac=0.8, timeout=9.0,
                           warmup_rounds=2)
    assert c.policy("m", 10).source == "static"
    c.observe_round("m", [0.1] * 10, 10)
    assert c.policy("m", 10).source == "static"   # 1 < warmup_rounds
    c.observe_round("m", [0.1] * 10, 10)
    pol = c.policy("m", 10)
    assert pol.source == "learned"
    # an unseen tenant borrows the cross-tenant prior (cold-start
    # transfer) once the pooled curve has warmup mass
    assert c.policy("other", 10).source == "prior"
    assert c.static_policy(10) == ClosePolicy(
        threshold=8, deadline=9.0, threshold_frac=0.8,
        expected_wait=9.0, source="static",
    )


def test_cost_bias_extremes():
    """b=1 maximizes inclusion (waits for the learned tail); b=0
    minimizes wall-clock (closes at the first attainable fraction)."""
    offsets = np.concatenate([np.linspace(0.05, 0.3, 8), [4.0, 5.0]])
    for_inclusion = _trained(1.0, offsets, 10).policy("m", 10)
    for_speed = _trained(0.0, offsets, 10).policy("m", 10)
    assert for_inclusion.threshold == 10       # waits for the 5 s tail
    assert for_inclusion.expected_wait == pytest.approx(5.0, abs=0.3)
    assert for_speed.threshold < for_inclusion.threshold
    assert for_speed.expected_wait < 0.5
    assert for_speed.deadline < for_inclusion.deadline


def test_balanced_bias_skips_expensive_tail():
    """At b=0.5 a 2-client tail costing 25 s is not worth 0.2 of
    inclusion weight ~0.1 — the policy stops at the cheap 80%."""
    offsets = np.concatenate([np.linspace(0.05, 0.4, 8), [25.0, 28.0]])
    pol = _trained(0.5, offsets, 10, timeout=30.0).policy("m", 10)
    assert pol.source == "learned"
    assert pol.threshold == 8
    assert pol.deadline < 5.0


def test_learned_deadline_never_exceeds_timeout():
    pol = _trained(1.0, [50.0] * 10, 10, timeout=10.0).policy("m", 10)
    assert pol.deadline <= 10.0


def test_dropout_fleet_learns_attainable_threshold():
    """8 of 10 arrive by 1 s, 2 NEVER arrive: the static gate burns the
    whole timeout; the learned gate thresholds at 8 with a ~1 s
    deadline — same inclusion, a fraction of the wall."""
    c = _trained(0.5, np.linspace(0.1, 1.0, 8), 10, timeout=30.0)
    pol = c.policy("m", 10)
    assert pol.source == "learned"
    assert pol.threshold == 8
    assert pol.deadline < 2.0
    assert pol(8, 0.9)            # closes on the 8th arrival
    assert not pol(7, 0.9)
    assert pol(7, pol.deadline)   # deadline backstop


def test_controller_state_dict_roundtrip():
    c = _trained(0.5, np.linspace(0.1, 1.0, 8), 10)
    c2 = AdaptiveController(cost_bias=0.5, threshold_frac=0.8,
                            timeout=30.0)
    c2.load_state_dict(c.state_dict())
    assert c2.tenants() == ["m"]
    assert c2.policy("m", 10) == c.policy("m", 10)


def test_controller_validates_cost_bias():
    with pytest.raises(ValueError):
        AdaptiveController(cost_bias=1.5)
    with pytest.raises(ValueError):
        AggregationService(fusion="fedavg", cost_bias=-0.1)


# -- planner objective ---------------------------------------------------------


def test_round_objective_monotonicity():
    pl = Planner()
    base = pl.round_objective(1.0, 0.8, cost_bias=0.5, horizon=30.0)
    # longer wait costs more; higher inclusion costs less
    assert pl.round_objective(5.0, 0.8, 0.5, 30.0) > base
    assert pl.round_objective(1.0, 0.95, 0.5, 30.0) < base
    # bias extremes collapse to a single term
    assert pl.round_objective(9.0, 0.1, cost_bias=1.0, horizon=30.0) \
        == pytest.approx(0.9)
    lo = pl.round_objective(3.0, 0.1, cost_bias=0.0, horizon=30.0)
    assert lo == pytest.approx(
        (3.0 + pl.overlap_drain_seconds) / 30.0
    )
    # fusing under the wait is free: est below the wait doesn't move it
    assert pl.round_objective(3.0, 0.5, 0.0, 30.0, est_seconds=1.0) \
        == pl.round_objective(3.0, 0.5, 0.0, 30.0)
    assert pl.round_objective(3.0, 0.5, 0.0, 30.0, est_seconds=9.0) \
        > pl.round_objective(3.0, 0.5, 0.0, 30.0)


# -- monitor pluggable policy --------------------------------------------------


def test_monitor_pluggable_policy_overrides_static_gate():
    clk = ScriptedClock()
    store = UpdateStore(clock=clk.clock)
    u, w = _mk(4)
    for i in range(3):
        clk.at(0.2 * (i + 1),
               lambda i=i: store.write(f"c{i}", u[i], weight=float(w[i])))
    pol = ClosePolicy(threshold=3, deadline=5.0, threshold_frac=0.75,
                      expected_wait=0.6, source="learned")
    mon = Monitor(store, threshold=3, timeout=60.0, poll_interval=0.1,
                  clock=clk.clock, sleep=clk.sleep, policy=pol)
    res = mon.wait()
    assert res.ready and res.count == 3
    assert res.waited < 1.0   # closed on the learned threshold, not 60 s


# -- service integration (scripted clock) --------------------------------------


def _adaptive_service(store, clk, **kw):
    kw.setdefault("threshold_frac", 1.0)
    kw.setdefault("monitor_timeout", 30.0)
    return AggregationService(
        fusion="fedavg", local_strategy="jnp", store=store,
        adaptive=True, clock=clk.clock, sleep=clk.sleep, **kw,
    )


def test_service_learns_to_close_dropout_rounds_early():
    """The end-to-end adaptive claim: expected 10, 8 arrive within 1 s,
    2 are permanently dropped. Round 1 (static gate) burns the full
    30 s timeout; round 2 uses the learned gate and closes in ~1 s at
    the same inclusion."""
    n, p = 8, 40
    u, w = _mk(n, p)
    clk = ScriptedClock()
    store = UpdateStore(clock=clk.clock)
    svc = _adaptive_service(store, clk)

    def schedule(base):
        for i in range(n):
            clk.at(base + 0.1 * (i + 1),
                   lambda i=i: store.write(f"c{i}", u[i],
                                           weight=float(w[i])))

    schedule(0.0)
    fused1, rep1 = svc.aggregate(from_store=True, expected_clients=10,
                                 async_round=True)
    assert rep1.close_policy.source == "static"
    assert rep1.monitor.waited >= 30.0       # static gate: full timeout
    assert rep1.n_clients == n

    schedule(clk.t)
    fused2, rep2 = svc.aggregate(from_store=True, expected_clients=10,
                                 async_round=True)
    assert rep2.close_policy.source == "learned"
    assert rep2.n_clients == n               # equal inclusion
    assert rep2.monitor.waited < 3.0         # ~10x faster close
    np.testing.assert_allclose(np.asarray(fused2), _fedavg(u, w),
                               rtol=1e-4, atol=1e-5)


def test_service_serialized_adaptive_round_learns_too():
    """The learned gate also drives serialized (non-async) store
    rounds: same dropout fleet, monitor.wait() closes early on round
    two."""
    n, p = 6, 32
    u, w = _mk(n, p)
    clk = ScriptedClock()
    store = UpdateStore(clock=clk.clock)
    svc = _adaptive_service(store, clk)

    def schedule(base):
        for i in range(n):
            clk.at(base + 0.2 * (i + 1),
                   lambda i=i: store.write(f"c{i}", u[i],
                                           weight=float(w[i])))

    schedule(0.0)
    _, rep1 = svc.aggregate(from_store=True, expected_clients=8)
    store.clear()
    assert rep1.monitor.waited >= 30.0
    schedule(clk.t)
    _, rep2 = svc.aggregate(from_store=True, expected_clients=8)
    assert rep2.close_policy.source == "learned"
    assert rep2.monitor.waited < 4.0
    assert rep2.n_clients == n


def test_per_tenant_carry_isolation():
    """Interleaved tenants with staleness_discount: each tenant's carry
    accumulator evolves from ITS rounds only."""
    p = 24
    u, w = _mk(6, p)
    g = 0.5
    clk = ScriptedClock()
    store = UpdateStore(clock=clk.clock)
    svc = AggregationService(
        fusion="fedavg", local_strategy="jnp", store=store,
        threshold_frac=1.0, monitor_timeout=0.5, staleness_discount=g,
        clock=clk.clock, sleep=clk.sleep,
    )

    def round_for(rows, weights, tenant):
        for cid, (uu, ww) in enumerate(zip(rows, weights)):
            store.write(f"{tenant}-{cid}", uu, weight=float(ww),
                        tenant=tenant)
        fused, rep = svc.aggregate(
            from_store=True, expected_clients=len(rows),
            async_round=True, tenant=tenant,
        )
        return np.asarray(fused), rep

    fused_a1, _ = round_for(u[:2], w[:2], "A")
    fused_b1, _ = round_for(u[2:4], w[2:4], "B")
    fused_a2, _ = round_for(u[4:5], w[4:5], "A")
    fused_b2, _ = round_for(u[5:6], w[5:6], "B")

    # tenant A's round 2 = gamma * A's sums + the new row — B never leaks
    ws_a1 = np.einsum("np,n->p", u[:2], w[:2])
    tot_a1 = w[:2].sum()
    exp_a2 = (g * ws_a1 + w[4] * u[4]) / (g * tot_a1 + w[4] + 1e-6)
    np.testing.assert_allclose(fused_a2, exp_a2, rtol=1e-4, atol=1e-5)
    ws_b1 = np.einsum("np,n->p", u[2:4], w[2:4])
    tot_b1 = w[2:4].sum()
    exp_b2 = (g * ws_b1 + w[5] * u[5]) / (g * tot_b1 + w[5] + 1e-6)
    np.testing.assert_allclose(fused_b2, exp_b2, rtol=1e-4, atol=1e-5)
    assert rep_tenants(svc) == {"A", "B"}


def rep_tenants(svc):
    return {r.tenant for r in svc.history}


def test_per_tenant_controller_isolation():
    """Two tenants with different arrival behavior learn different
    gates through one service."""
    c = AdaptiveController(cost_bias=0.5, threshold_frac=1.0,
                           timeout=30.0)
    for _ in range(3):
        c.observe_round("fast", np.linspace(0.01, 0.1, 10), 10)
        c.observe_round("slow", np.linspace(0.5, 8.0, 10), 10)
    fast, slow = c.policy("fast", 10), c.policy("slow", 10)
    assert fast.deadline < slow.deadline
    assert fast.expected_wait < slow.expected_wait


# -- store arrival capture + event-driven tailing ------------------------------


def test_store_arrival_times_follow_store_clock():
    clk = ScriptedClock()
    store = UpdateStore(clock=clk.clock)
    store.write("a", np.ones(4, np.float32))
    clk.sleep(2.5)
    store.write("b", np.ones(4, np.float32))
    at = store.arrival_times()
    assert at["b"] - at["a"] == pytest.approx(2.5)
    store.remove(["a"])
    assert "a" not in store.arrival_times()
    store.clear()
    assert store.arrival_times() == {}


def test_wait_for_arrival_wakes_on_write_not_timeout():
    """The arrival condition wakes a real-clock waiter as soon as a
    write lands — it does not sleep out the full poll window."""
    store = UpdateStore()
    t = threading.Timer(
        0.15, lambda: store.write("x", np.ones(4, np.float32))
    )
    t.start()
    t0 = time.perf_counter()
    store.wait_for_arrival(timeout=10.0)
    elapsed = time.perf_counter() - t0
    t.join()
    assert store.count() == 1
    assert elapsed < 5.0, "waiter slept through the arrival notify"


def test_spool_tailer_ingests_external_writes(tmp_path):
    """Blobs dropped into the spool by an external process (bypassing
    write()) are registered by the tailer — weights from the sidecar,
    arrival timestamp stamped, visible to count()/reads."""
    store = UpdateStore(backend="disk", spool_dir=str(tmp_path))
    with SpoolTailer(store, poll_interval=0.05) as tailer:
        # the writer runs AFTER the tailer's start()-time catch-up
        # ingest, so registration exercises tailing proper
        def foreign_writer():
            np.save(tmp_path / "ext0.npy", np.full(8, 3.0, np.float32))
            with open(tmp_path / "ext0.npy.w", "w") as f:
                f.write("2.5")
        th = threading.Thread(target=foreign_writer)
        th.start()
        # event-driven wait: woken by the tailer's registration, no
        # fixed sleep-and-poll
        deadline = time.time() + 5.0
        while store.count() < 1 and time.time() < deadline:
            store.wait_for_arrival(timeout=0.2)
        th.join()
        assert store.count() == 1, "tailer never saw the external blob"
        upd, weight = store.read("ext0")
        assert weight == 2.5
        np.testing.assert_array_equal(np.asarray(upd),
                                      np.full(8, 3.0, np.float32))
        assert "ext0" in store.arrival_times()
    # stopped: the context exit JOINED the tailer thread, so a later
    # foreign write cannot be auto-registered (no settle sleep needed)
    np.save(tmp_path / "ext1.npy", np.ones(8, np.float32))
    assert store.count() == 1


def test_ingest_external_skips_partial_blobs(tmp_path):
    # grace windows run on the injected WALL clock: expiry is scripted,
    # not slept out
    wall = ScriptedClock()
    store = UpdateStore(backend="disk", spool_dir=str(tmp_path),
                        sidecar_grace_seconds=0.05,
                        wall_clock=wall.clock)
    (tmp_path / "broken.npy").write_bytes(b"\x93NUMPY garbage")
    np.save(tmp_path / "good.npy", np.ones(4, np.float32))
    # a blob with no sidecar defers for the grace window (the sidecar
    # may still be in flight behind the blob)
    assert store.ingest_external() == []
    wall.sleep(0.1)
    assert store.ingest_external() == ["good"]
    assert store.client_ids() == ["good"]
    _, weight = store.read("good")
    assert weight == 1.0   # still no sidecar: default weight
    # later passes are idempotent
    assert store.ingest_external() == []


def test_ingest_external_waits_for_inflight_sidecar(tmp_path):
    """The review race: blob lands and MULTIPLE ingest passes run
    before the sidecar is written — the update must register with the
    sidecar's weight, not freeze at the 1.0 default."""
    wall = ScriptedClock()
    store = UpdateStore(backend="disk", spool_dir=str(tmp_path),
                        wall_clock=wall.clock)
    np.save(tmp_path / "c7.npy", np.ones(4, np.float32))
    assert store.ingest_external() == []          # within grace
    assert store.ingest_external() == []          # event-storm re-pass
    with open(tmp_path / "c7.npy.w", "w") as f:   # sidecar lands late
        f.write("42.0")
    assert store.ingest_external() == ["c7"]
    _, weight = store.read("c7")
    assert weight == 42.0


def test_spool_tailer_rejects_memory_backend():
    with pytest.raises(ValueError):
        SpoolTailer(UpdateStore())


def test_tailed_arrivals_feed_async_round(tmp_path):
    """End to end: external spool writes only, discovered by the
    tailer, folded by an async round's arrival stream."""
    u, w = _mk(5, 16)
    store = UpdateStore(backend="disk", spool_dir=str(tmp_path))
    svc = AggregationService(
        fusion="fedavg", local_strategy="jnp", store=store,
        threshold_frac=1.0, monitor_timeout=10.0, poll_interval=0.02,
    )

    def foreign_writer():
        # no pacing sleeps: the tailer's own poll cadence already
        # staggers discovery relative to the open round
        for i in range(5):
            np.save(tmp_path / f"e{i}.npy", u[i])
            with open(tmp_path / f"e{i}.npy.w", "w") as f:
                f.write(repr(float(w[i])))

    with SpoolTailer(store, poll_interval=0.05):
        th = threading.Thread(target=foreign_writer)
        th.start()
        fused, rep = svc.aggregate(from_store=True, expected_clients=5,
                                   async_round=True)
        th.join()
    assert rep.n_clients == 5 and rep.monitor.ready
    np.testing.assert_allclose(np.asarray(fused), _fedavg(u, w),
                               rtol=1e-4, atol=1e-5)
