import os

# Tests run on the real single CPU device (the dry-run alone forces 512
# host devices, in its own process). Keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest

try:  # five modules property-test via hypothesis; the container lacks it
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_fallback

    _hypothesis_fallback.install()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def lock_witness(monkeypatch):
    """Opt-in runtime lock-order witness (repro.analysis.witness).

    Every AggregationService constructed while the fixture is active
    gets its state/store/round lock layers wrapped, recording the
    cross-thread acquisition graph; teardown fails the test on cycles
    or on orderings contradicting the declared partial order
    (state ≺ store ≺ round, inner-first).
    """
    from repro.analysis.witness import LockOrderWitness, instrument_service
    from repro.core.service import AggregationService

    witness = LockOrderWitness()
    orig_init = AggregationService.__init__

    def patched(self, *args, **kwargs):
        orig_init(self, *args, **kwargs)
        instrument_service(self, witness)

    monkeypatch.setattr(AggregationService, "__init__", patched)
    yield witness
    witness.check()
