import os

# Tests run on the real single CPU device (the dry-run alone forces 512
# host devices, in its own process). Keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest

try:  # five modules property-test via hypothesis; the container lacks it
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_fallback

    _hypothesis_fallback.install()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
