import os

# Tests run on the real single CPU device (the dry-run alone forces 512
# host devices, in its own process). Keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
