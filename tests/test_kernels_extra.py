"""Shape/dtype sweeps for the ssd_chunk and flash_decode Pallas kernels."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode.kernel import flash_decode
from repro.kernels.ssd_chunk.ops import ssd_scan
from repro.kernels.ssd_chunk.ref import ssd_chunk_ref
from repro.models.cache import cache_valid_mask
from repro.models.layers.attention import decode_attention
from repro.models.layers.mamba2 import (
    Mamba2Dims,
    init_mamba2,
    mamba2_forward,
)

RNG = np.random.default_rng(17)


# -- ssd_chunk ---------------------------------------------------------------


@pytest.mark.parametrize("B,T,H,N,P,L", [
    (1, 32, 1, 8, 8, 8),
    (2, 64, 3, 8, 16, 16),
    (2, 128, 2, 16, 32, 32),
])
def test_ssd_chunk_vs_ref(B, T, H, N, P, L):
    lam = jnp.asarray(
        -np.abs(RNG.normal(size=(B, T, H))).astype(np.float32) * 0.1
    )
    Bm = jnp.asarray(RNG.normal(size=(B, T, N)).astype(np.float32))
    Cm = jnp.asarray(RNG.normal(size=(B, T, N)).astype(np.float32))
    xdt = jnp.asarray(RNG.normal(size=(B, T, H, P)).astype(np.float32))
    y = ssd_scan(lam, Bm, Cm, xdt, chunk=L)
    for b in range(B):
        for h in range(H):
            yr, _ = ssd_chunk_ref(
                lam[b, :, h].reshape(-1, L),
                Bm[b].reshape(-1, L, N),
                Cm[b].reshape(-1, L, N),
                xdt[b, :, h].reshape(-1, L, P),
                jnp.zeros((N, P)),
            )
            np.testing.assert_allclose(
                np.asarray(y[b, :, h]), np.asarray(yr).reshape(T, P),
                rtol=1e-4, atol=1e-4,
            )


def test_ssd_kernel_matches_model_layer():
    """The kernel reproduces the full Mamba2 layer's SSD core: run the
    model layer with D-skip/gating stripped out analytically."""
    dims = Mamba2Dims(d_model=16, d_inner=32, n_heads=2, head_dim=16,
                      state=8, conv_width=4, chunk=8)
    # direct SSD comparison at the tensor level (no projections): the
    # model's chunk_step math IS ssd_chunk_ref (asserted in its docstring);
    # here assert kernel == ref at model-like sizes incl. dtype bf16 input
    B, T, H, N, P, L = 1, 64, 2, 8, 16, 8
    lam = jnp.asarray(
        -np.abs(RNG.normal(size=(B, T, H))).astype(np.float32) * 0.05
    )
    Bm = jnp.asarray(RNG.normal(size=(B, T, N))).astype(jnp.bfloat16)
    Cm = jnp.asarray(RNG.normal(size=(B, T, N))).astype(jnp.bfloat16)
    xdt = jnp.asarray(RNG.normal(size=(B, T, H, P))).astype(jnp.bfloat16)
    y = ssd_scan(lam, Bm, Cm, xdt, chunk=L)
    yr, _ = ssd_chunk_ref(
        lam[0, :, 0].reshape(-1, L),
        Bm[0].astype(jnp.float32).reshape(-1, L, N),
        Cm[0].astype(jnp.float32).reshape(-1, L, N),
        xdt[0, :, 0].astype(jnp.float32).reshape(-1, L, P),
        jnp.zeros((N, P)),
    )
    np.testing.assert_allclose(
        np.asarray(y[0, :, 0]), np.asarray(yr).reshape(T, P),
        rtol=5e-2, atol=5e-2,  # bf16 inputs
    )


# -- flash_decode ------------------------------------------------------------


@pytest.mark.parametrize("S,nq,nkv,hd,block", [
    (128, 8, 2, 32, 32),
    (256, 4, 4, 64, 64),   # MHA
    (128, 8, 1, 64, 128),  # MQA
])
@pytest.mark.parametrize("pos", [5, 127, 400])
def test_flash_decode_vs_model(S, nq, nkv, hd, block, pos):
    B = 2
    q = jnp.asarray(RNG.normal(size=(B, 1, nq, hd)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(B, S, nkv, hd)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(B, S, nkv, hd)).astype(np.float32))
    valid = cache_valid_mask(S, jnp.int32(pos), B)
    ref = decode_attention(q, k, v, valid)
    out = flash_decode(q, k, v, jnp.int32(pos), block_s=block)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


def test_flash_decode_bf16():
    B, S, nq, nkv, hd = 2, 128, 4, 2, 64
    mk = lambda s: jnp.asarray(RNG.normal(size=s)).astype(jnp.bfloat16)
    q, k, v = mk((B, 1, nq, hd)), mk((B, S, nkv, hd)), mk((B, S, nkv, hd))
    valid = cache_valid_mask(S, jnp.int32(64), B)
    ref = decode_attention(q, k, v, valid)
    out = flash_decode(q, k, v, jnp.int32(64), block_s=64)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=5e-2, atol=5e-2,
    )
