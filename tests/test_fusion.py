"""Unit + property tests for the fusion-algorithm library."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core.fusion import (
    ClippedAvg,
    CoordMedian,
    FedAdam,
    FedAvg,
    FedAvgM,
    GeometricMedian,
    GradAvg,
    IterAvg,
    Krum,
    TrimmedMean,
    Zeno,
    get_fusion,
)

RNG = np.random.default_rng(42)


def _updates(n=8, p=33):
    return (
        RNG.normal(size=(n, p)).astype(np.float32),
        RNG.uniform(1, 10, size=(n,)).astype(np.float32),
    )


def test_fedavg_matches_paper_eq1():
    u, w = _updates()
    out = np.asarray(FedAvg().fuse(jnp.asarray(u), jnp.asarray(w)))
    expect = (u * w[:, None]).sum(0) / (w.sum() + 1e-6)
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-6)


def test_iteravg_ignores_weights():
    u, w = _updates()
    a = np.asarray(IterAvg().fuse(jnp.asarray(u), jnp.asarray(w)))
    b = np.asarray(IterAvg().fuse(jnp.asarray(u), jnp.ones_like(w)))
    np.testing.assert_allclose(a, b, rtol=1e-6)
    np.testing.assert_allclose(a, u.mean(0), rtol=1e-4, atol=1e-5)


def test_clippedavg_clips_outlier():
    u, w = _updates()
    u[0] *= 1e4  # one huge update
    f = ClippedAvg(clip_norm=5.0)
    out = np.asarray(f.fuse(jnp.asarray(u), jnp.asarray(w)))
    assert np.isfinite(out).all()
    # fused result must stay bounded by the clip norm
    assert np.linalg.norm(out) <= 5.0 + 1e-3


def test_coordmedian_robust_to_minority():
    u, w = _updates(n=9)
    u[:3] = 1e6  # 3 of 9 byzantine
    out = np.asarray(CoordMedian().fuse(jnp.asarray(u), jnp.asarray(w)))
    assert np.abs(out).max() < 100.0


def test_trimmedmean_drops_extremes():
    u = np.vstack([np.full((1, 5), -1e6), RNG.normal(size=(6, 5)),
                   np.full((1, 5), 1e6)]).astype(np.float32)
    out = np.asarray(TrimmedMean(beta=0.2).fuse(jnp.asarray(u), None))
    np.testing.assert_allclose(out, u[1:7].mean(0), rtol=1e-4, atol=1e-4)


def test_krum_rejects_byzantine():
    u, w = _updates(n=10, p=16)
    u[0] = 500.0  # attacker far from the cluster
    out = np.asarray(
        Krum(n_byzantine=1, m=1).fuse(jnp.asarray(u), jnp.asarray(w))
    )
    # selected update is one of the honest ones
    dists = np.linalg.norm(u - out[None], axis=1)
    assert dists.argmin() != 0


def test_multikrum_averages_m():
    u, w = _updates(n=10, p=16)
    f = Krum(n_byzantine=1, m=3)
    out = np.asarray(f.fuse(jnp.asarray(u), jnp.asarray(w)))
    assert out.shape == (16,)


def test_zeno_drops_suspicious():
    u, w = _updates(n=6, p=8)
    g_val = np.ones(8, np.float32)
    u[0] = -50 * g_val  # opposes the validation gradient
    f = Zeno(rho=1e-3, n_suspect=1)
    f.set_val_grad(jnp.asarray(g_val))
    out = np.asarray(f.fuse(jnp.asarray(u), jnp.asarray(w)))
    np.testing.assert_allclose(out, u[1:].mean(0), rtol=1e-4, atol=1e-4)


def test_geomedian_close_to_median_under_outlier():
    u, w = _updates(n=9, p=4)
    u[0] = 1e5
    w = np.ones_like(w)
    out = np.asarray(GeometricMedian(iters=32).fuse(
        jnp.asarray(u), jnp.asarray(w)))
    assert np.abs(out).max() < 1e3


def test_server_optimizers_stateful():
    u, w = _updates(n=4, p=6)
    f = FedAvgM(lr=1.0, momentum=0.5)
    out1 = np.asarray(f.fuse(jnp.asarray(u), jnp.asarray(w)))
    out2 = np.asarray(f.fuse(jnp.asarray(u), jnp.asarray(w)))
    # second round has momentum: v2 = 0.5 v1 + g = 1.5 g
    np.testing.assert_allclose(out2, 1.5 * out1, rtol=1e-4, atol=1e-5)
    a = FedAdam(lr=0.1)
    o1 = np.asarray(a.fuse(jnp.asarray(u), jnp.asarray(w)))
    assert np.isfinite(o1).all() and o1.shape == (6,)


# -- property tests ----------------------------------------------------------

small_mat = hnp.arrays(
    np.float32, st.tuples(st.integers(2, 12), st.integers(1, 24)),
    elements=st.floats(-100, 100, width=32),
)


@settings(max_examples=40, deadline=None)
@given(u=small_mat, seed=st.integers(0, 2**16))
def test_fedavg_permutation_invariant(u, seed):
    r = np.random.default_rng(seed)
    w = r.uniform(1, 5, size=u.shape[0]).astype(np.float32)
    perm = r.permutation(u.shape[0])
    a = np.asarray(FedAvg().fuse(jnp.asarray(u), jnp.asarray(w)))
    b = np.asarray(FedAvg().fuse(jnp.asarray(u[perm]), jnp.asarray(w[perm])))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


@settings(max_examples=40, deadline=None)
@given(u=small_mat)
def test_median_within_bounds(u):
    out = np.asarray(CoordMedian().fuse(jnp.asarray(u), None))
    assert (out >= u.min(0) - 1e-5).all()
    assert (out <= u.max(0) + 1e-5).all()


@settings(max_examples=40, deadline=None)
@given(u=small_mat, c=st.floats(0.1, 10.0))
def test_fedavg_scale_equivariant(u, c):
    w = np.ones(u.shape[0], np.float32)
    a = np.asarray(FedAvg().fuse(jnp.asarray(u * c), jnp.asarray(w)))
    b = np.asarray(FedAvg().fuse(jnp.asarray(u), jnp.asarray(w)))
    np.testing.assert_allclose(a, c * b, rtol=1e-3, atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(u=small_mat)
def test_fedavg_equal_weights_is_iteravg(u):
    w = np.full(u.shape[0], 7.0, np.float32)
    a = np.asarray(FedAvg().fuse(jnp.asarray(u), jnp.asarray(w)))
    b = np.asarray(IterAvg().fuse(jnp.asarray(u), jnp.asarray(w)))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_registry_complete():
    for name in ("fedavg", "iteravg", "gradavg", "clippedavg", "coordmedian",
                 "trimmedmean", "krum", "zeno", "geomedian", "fedavgm",
                 "fedadam"):
        assert get_fusion(name).name == name
