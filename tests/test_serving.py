"""Serving-layer battery: the HTTP ingest front-end end to end.

The core claim under test is TRANSPORT TRANSPARENCY: a round fused
from socket-ingested updates is bit-identical to the same round fused
from in-process ``store.write`` calls — dense, compressed, and mixed.
Around it: every admission-control rejection path (401/400/413/429/503)
rejects WITHOUT landing anything, backpressure is explicit, and a
PR-8 ``WorkloadSpec`` trace replays over real sockets as the
multi-tenant smoke. The ``--quick`` ingest benchmark runs as a
subprocess gate at the end (mirrors test_soak.py's pattern).
"""
import json
import os
import socket
import subprocess
import sys
import threading
import time
import urllib.request

import numpy as np
import pytest

from repro.core import (
    AggregationService,
    FairRoundScheduler,
    UpdateStore,
)
from repro.core.compress import compress_update
from repro.serving import (
    BackpressureError,
    HttpStoreClient,
    IngestError,
    IngestQueue,
    IngestServer,
    encode_update,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

TOKENS = {"tok-a": "appa", "tok-b": "appb"}
CLIENT_TOKENS = {"appa": "tok-a", "appb": "tok-b"}


def _mk_service(store, timeout=5.0, **kw):
    return AggregationService(
        fusion="fedavg", local_strategy="jnp", store=store,
        threshold_frac=1.0, monitor_timeout=timeout, **kw,
    )


def _payloads(n, p, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.normal(size=(p,)).astype(np.float32) for _ in range(n)]


def _post_raw(port, body, token="tok-a", path="/v1/upload",
              content_length=None):
    """One raw POST, returning (status, headers, body)."""
    req = urllib.request.Request(
        f"http://127.0.0.1:{port}{path}", data=body, method="POST",
        headers={"Authorization": f"Bearer {token}",
                 "Content-Type": "application/octet-stream"},
    )
    if content_length is not None:
        req.add_header("Content-Length", str(content_length))
    try:
        with urllib.request.urlopen(req, timeout=10) as resp:
            return resp.status, dict(resp.headers), resp.read()
    except urllib.error.HTTPError as e:
        return e.code, dict(e.headers), e.read()


# -- e2e exactness -----------------------------------------------------------

@pytest.mark.parametrize("mode", ["dense", "compressed", "mixed"])
def test_socket_round_bit_identical_to_inprocess(mode):
    """upload -> round == store.write -> round, bitwise, for dense,
    compressed, and mixed payload populations."""
    n, p = 6, 1500
    payloads = _payloads(n, p)

    def u_for(i, vec):
        if mode == "dense" or (mode == "mixed" and i % 2 == 0):
            return vec
        return compress_update(vec, block=256)

    # reference: in-process writes on a private store/service
    ref_store = UpdateStore()
    for i, vec in enumerate(payloads):
        ref_store.write(f"c{i}", u_for(i, vec), weight=1.0 + i,
                        tenant="appa")
    ref_fused, ref_rep = _mk_service(ref_store).aggregate(
        from_store=True, expected_clients=n, tenant="appa")

    # same updates over real sockets
    store = UpdateStore()
    svc = _mk_service(store)
    with IngestServer(store, TOKENS) as srv:
        cli = HttpStoreClient("127.0.0.1", srv.port,
                              tokens=CLIENT_TOKENS)
        for i, vec in enumerate(payloads):
            cli.write(f"c{i}", u_for(i, vec), weight=1.0 + i,
                      tenant="appa")
        fused, rep = svc.aggregate(from_store=True,
                                   expected_clients=n, tenant="appa")
    assert rep.n_clients == ref_rep.n_clients == n
    a, b = np.asarray(fused), np.asarray(ref_fused)
    assert a.dtype == b.dtype
    assert np.array_equal(a, b), "socket round diverged bitwise"


def test_upload_weights_and_bytes_land_exactly():
    store = UpdateStore()
    vec = np.arange(300, dtype=np.float32)
    with IngestServer(store, TOKENS) as srv:
        cli = HttpStoreClient("127.0.0.1", srv.port, token="tok-a")
        lat = cli.write("c0", vec, weight=3.5, tenant="appa")
        assert lat > 0   # the modeled store latency came back
        got, w = store.read("c0", tenant="appa")
        assert w == 3.5
        assert np.array_equal(np.asarray(got), vec)
        st = store.stats_for("appa")
        assert st.writes == 1
        assert st.bytes_written == vec.nbytes * store.replication


# -- auth / malformed / oversized: fail closed -------------------------------

def test_bad_token_is_401_and_lands_nothing():
    store = UpdateStore()
    with IngestServer(store, TOKENS) as srv:
        body = encode_update("c0", np.ones(8, np.float32))
        status, _, _ = _post_raw(srv.port, body, token="tok-nope")
        assert status == 401
        status, _, _ = _post_raw(srv.port, body, token="")
        assert status == 401
    assert store.count() == 0


def test_unknown_route_is_404():
    with IngestServer(UpdateStore(), TOKENS) as srv:
        status, _, _ = _post_raw(srv.port, b"x", path="/v1/nope")
        assert status == 404
        status = urllib.request.urlopen(
            f"http://127.0.0.1:{srv.port}/v1/healthz", timeout=5
        ).status
        assert status == 200


@pytest.mark.parametrize("mangle", [
    lambda b: b[:-3],                      # truncated tail
    lambda b: b + b"\x00\x01",             # trailing garbage
    lambda b: b"XXXX" + b[4:],             # bad magic
    lambda b: b[:4] + b"\x07" + b[5:],     # unknown kind
    lambda b: b"",                         # empty body
])
def test_malformed_frame_is_400_and_lands_nothing(mangle):
    store = UpdateStore()
    good = encode_update("c0", np.ones(64, np.float32), weight=2.0)
    with IngestServer(store, TOKENS) as srv:
        status, _, body = _post_raw(srv.port, mangle(good))
        assert status == 400, body
        assert store.count() == 0
        # the connection / server stay usable after a reject
        status, _, _ = _post_raw(srv.port, good)
        assert status == 200
    assert store.count() == 1


def test_oversized_body_is_413_and_lands_nothing():
    store = UpdateStore()
    with IngestServer(store, TOKENS, max_body_bytes=1024) as srv:
        body = encode_update("c0", np.ones(4096, np.float32))
        status, _, _ = _post_raw(srv.port, body)
        assert status == 413
        assert srv.metrics().get("shed_413") == 1
    assert store.count() == 0


def test_missing_content_length_is_411():
    with IngestServer(UpdateStore(), TOKENS) as srv:
        # raw socket: POST with no Content-Length at all
        s = socket.create_connection(("127.0.0.1", srv.port),
                                     timeout=5)
        try:
            s.sendall(b"POST /v1/upload HTTP/1.1\r\n"
                      b"Host: x\r\nAuthorization: Bearer tok-a\r\n"
                      b"\r\n")
            resp = s.recv(4096)
        finally:
            s.close()
        assert b"411" in resp.split(b"\r\n", 1)[0]


# -- rate limiting / quotas --------------------------------------------------

def test_rate_limit_429_with_retry_after_and_no_partial_blob():
    store = UpdateStore()
    with IngestServer(store, TOKENS, rate=1e-3, burst=2.0) as srv:
        body = encode_update("c0", np.ones(32, np.float32))
        # burst=2 admits two, third sheds
        assert _post_raw(srv.port, body)[0] == 200
        assert _post_raw(srv.port,
                         encode_update("c1",
                                       np.ones(32, np.float32)))[0] \
            == 200
        status, headers, _ = _post_raw(
            srv.port, encode_update("c2", np.ones(32, np.float32)))
        assert status == 429
        assert float(headers["Retry-After"]) > 0
        # the shed upload landed NOTHING; the admitted two are intact
        assert store.count(tenant="appa") == 2
        assert sorted(store.client_ids(tenant="appa")) == ["c0", "c1"]
        # and rate limits are per tenant: appb is unaffected
        status, _, _ = _post_raw(
            srv.port, encode_update("b0", np.ones(32, np.float32)),
            token="tok-b")
        assert status == 200


def test_quota_429_never_lands_a_partial_blob(tmp_path):
    """Quota rejection on a DISK store: no orphan file, no index entry,
    byte accounting untouched."""
    store = UpdateStore(backend="disk", spool_dir=str(tmp_path))
    store.set_quota("appa", max_updates=2, policy="reject")
    with IngestServer(store, TOKENS) as srv:
        cli = HttpStoreClient("127.0.0.1", srv.port, token="tok-a",
                              max_attempts=2, sleep=lambda s: None)
        cli.write("c0", np.ones(64, np.float32), tenant="appa")
        cli.write("c1", np.ones(64, np.float32), tenant="appa")

        def spool_files():
            return sorted(
                os.path.join(r, f)
                for r, _, fs in os.walk(tmp_path) for f in fs
            )

        before = spool_files()
        bytes_before = store.tenant_bytes("appa")
        with pytest.raises(IngestError) as ei:
            cli.write("c2", np.ones(64, np.float32), tenant="appa")
        assert "429" in str(ei.value) or ei.value.status == 429
        assert store.count(tenant="appa") == 2
        assert store.tenant_bytes("appa") == bytes_before
        assert spool_files() == before, "429 left an orphan blob"
        assert srv.metrics().get("shed_429", 0) >= 1


def test_store_quota_reject_at_commit_time_is_429(tmp_path):
    """With the admission pre-check disabled, the store's own quota
    check at commit time is authoritative: it surfaces as the same 429,
    lands nothing — and, unlike the door pre-check, it KNOWS the
    client_id, so replacing a resident client at full count quota
    works."""
    from repro.serving import AdmissionController

    store = UpdateStore(backend="disk", spool_dir=str(tmp_path))
    store.set_quota("appa", max_updates=2, policy="reject")
    admission = AdmissionController(TOKENS)   # no store: no pre-check
    with IngestServer(store, TOKENS, admission=admission) as srv:
        cli = HttpStoreClient("127.0.0.1", srv.port, token="tok-a",
                              max_attempts=2, sleep=lambda s: None)
        cli.write("c0", np.ones(64, np.float32), tenant="appa")
        cli.write("c1", np.ones(64, np.float32), tenant="appa")
        with pytest.raises(IngestError):
            cli.write("c2", np.ones(64, np.float32), tenant="appa")
        assert srv.metrics().get("quota_reject", 0) >= 1
        assert store.count(tenant="appa") == 2
        # replacement of a RESIDENT client still fits the count quota
        assert cli.write("c0", np.zeros(64, np.float32),
                         tenant="appa") > 0
        got, _ = store.read("c0", tenant="appa")
        assert not np.any(np.asarray(got))


# -- backpressure ------------------------------------------------------------

class _GatedStore:
    """Store proxy whose write_batch blocks on an Event — makes the
    committer hang so the IngestQueue saturates deterministically."""

    def __init__(self, store, gate):
        self._store = store
        self._gate = gate

    def write_batch(self, items):
        self._gate.wait(timeout=30)
        return self._store.write_batch(items)

    def __getattr__(self, name):
        return getattr(self._store, name)


def test_backpressure_503_when_queue_saturated():
    store = UpdateStore()
    gate = threading.Event()
    gated = _GatedStore(store, gate)
    q = IngestQueue(gated, maxsize=2, batch_max=2)
    with IngestServer(store, TOKENS, ingest_queue=q,
                      commit_timeout=30.0) as srv:
        # saturate deterministically: the committer picks up the first
        # submission (depth drains to 0), then two more fill the queue
        futs = [q.submit("h0", np.ones(16, np.float32))]
        deadline = time.time() + 5
        while q.depth() > 0 and time.time() < deadline:
            time.sleep(0.01)
        assert q.depth() == 0, "committer never picked up the head"
        futs.append(q.submit("h1", np.ones(16, np.float32)))
        futs.append(q.submit("h2", np.ones(16, np.float32)))
        assert q.depth() == 2
        # the front-end must now shed with 503 + Retry-After
        body = encode_update("c99", np.ones(16, np.float32))
        status, headers, _ = _post_raw(srv.port, body)
        assert status == 503
        assert float(headers["Retry-After"]) > 0
        assert srv.metrics().get("backpressure") == 1
        assert q.stats()["shed"] >= 1
        gate.set()           # release the committer; queued commits land
        for f in futs:
            assert f.result(timeout=10) > 0
        # and the SAME upload succeeds once pressure clears
        status, _, _ = _post_raw(srv.port, body)
        assert status == 200
    assert store.count() == 4
    assert "c99" in store.client_ids()
    assert sorted(store.client_ids()) == ["c99", "h0", "h1", "h2"]


def test_ingest_queue_backpressure_error_direct():
    gate = threading.Event()
    q = IngestQueue(_GatedStore(UpdateStore(), gate), maxsize=1,
                    batch_max=4)
    q.submit("a", np.ones(4, np.float32))
    deadline = time.time() + 5
    while q.depth() > 0 and time.time() < deadline:
        time.sleep(0.01)   # committer picked up the first
    q.submit("b", np.ones(4, np.float32))   # fills the queue
    with pytest.raises(BackpressureError) as ei:
        q.submit("c", np.ones(4, np.float32))
    assert ei.value.retry_after > 0
    gate.set()
    q.close()


# -- batched commits ---------------------------------------------------------

@pytest.mark.usefixtures("lock_witness")
def test_concurrent_uploads_coalesce_into_batches():
    store = UpdateStore()
    gate = threading.Event()
    q = IngestQueue(_GatedStore(store, gate), maxsize=64, batch_max=16)
    futs = [q.submit(f"c{i}", np.full(8, i, np.float32),
                     weight=1.0, tenant="appa") for i in range(12)]
    gate.set()
    for f in futs:
        assert f.result(timeout=10) > 0
    stats = q.stats()
    q.close()
    assert stats["committed"] == 12
    # the first submit may slip through alone, but the stalled rest
    # must coalesce: strictly fewer batches than updates
    assert stats["batches"] < 12
    assert stats["max_batch"] > 1
    assert store.count(tenant="appa") == 12


# -- fair scheduler ----------------------------------------------------------

class _FakeService:
    """Records aggregate() concurrency; no jax, no store."""

    def __init__(self):
        self.lock = threading.Lock()
        self.active = 0
        self.peak = 0
        self.calls = []
        self.store = None
        self.block = threading.Event()
        self.block.set()

    def aggregate(self, tenant=None, **kw):
        with self.lock:
            self.active += 1
            self.peak = max(self.peak, self.active)
            self.calls.append(tenant)
        self.block.wait(timeout=10)
        time.sleep(0.01)
        with self.lock:
            self.active -= 1
        return (np.zeros(2), None)

    def _row_bytes(self, p, dtype):
        return p * 4

    def _chunk_rows(self, n, row_bytes):
        return n


def test_fair_scheduler_bounds_concurrency():
    svc = _FakeService()
    svc.block.clear()
    with FairRoundScheduler(svc, max_running=2) as sched:
        futs = [sched.submit(f"t{i}") for i in range(6)]
        deadline = time.time() + 5
        while len(sched.running()) < 2 and time.time() < deadline:
            time.sleep(0.01)
        assert len(sched.running()) == 2
        svc.block.set()
        for f in futs:
            f.result(timeout=10)
    assert svc.peak <= 2
    assert sorted(svc.calls) == sorted(f"t{i}" for i in range(6))


def test_fair_scheduler_weighted_share():
    """Under contention (max_running=1, standing backlog) a weight-2
    tenant is admitted twice as often as a weight-1 tenant."""
    svc = _FakeService()
    sched = FairRoundScheduler(svc, max_running=1,
                               weights={"heavy": 2.0, "light": 1.0})
    try:
        futs = [sched.submit("heavy") for _ in range(8)] + \
               [sched.submit("light") for _ in range(4)]
        for f in futs:
            f.result(timeout=30)
        order = sched.admission_order()
        # every prefix of the admission order respects the 2:1 ratio
        # within WFQ's one-round tolerance
        for i in range(1, len(order) + 1):
            h = order[:i].count("heavy")
            l = order[:i].count("light")
            assert abs(h - 2 * l) <= 2, (
                f"2:1 share violated at prefix {i}: {order[:i]}")
    finally:
        sched.shutdown()


def test_fair_scheduler_same_tenant_rounds_serialize():
    svc = _FakeService()
    with FairRoundScheduler(svc, max_running=4) as sched:
        futs = [sched.submit("only") for _ in range(3)]
        for f in futs:
            f.result(timeout=10)
    assert svc.peak == 1   # one in flight per tenant, ever


def test_fair_scheduler_capacity_gate():
    """A tenant whose projected footprint busts capacity waits until
    the running set drains — but runs alone rather than deadlocking."""
    svc = _FakeService()

    class _Meta:
        def meta(self, tenant):
            return (4, 1000, np.float32)   # footprint 2*4*4000 = 32000

    svc.store = _Meta()
    svc.block.clear()
    with FairRoundScheduler(svc, max_running=2,
                            capacity_bytes=40_000) as sched:
        f1 = sched.submit("a")
        deadline = time.time() + 5
        while not sched.running() and time.time() < deadline:
            time.sleep(0.01)
        # b's 32000 + a's 32000 > 40000 -> b must wait despite a free
        # slot
        f2 = sched.submit("b")
        time.sleep(0.3)
        assert sched.running() == ["a"]
        assert sched.waiting().get("b") == 1
        svc.block.set()
        f1.result(timeout=10)
        f2.result(timeout=10)
    assert sorted(svc.calls) == ["a", "b"]


# -- trace-replayed multi-tenant smoke (the tier-1 gate) ---------------------

@pytest.mark.usefixtures("lock_witness")
def test_trace_replayed_multitenant_smoke():
    """PR 8's WorkloadSpec driving the serving stack: K tenants replay
    a seeded trace over real sockets, rounds run through the fair
    scheduler, and every tenant's fused vector matches the formula."""
    from repro.fl import EdgeAggregatorServer
    from repro.workload import (
        FixedSize, RegimeSchedule, UniformArrivals, WorkloadSpec,
        start_writer, trace_payload,
    )

    k, n, p, seed = 3, 8, 600, 7
    spec = WorkloadSpec(
        tenants=tuple(f"app{i}" for i in range(k)),
        n_clients=n, rounds=1,
        regimes=RegimeSchedule.single(UniformArrivals(spread=0.2)),
        sizes=FixedSize(dim=p),
    )
    trace = spec.build(seed)
    tenants = [tr.tenant for tr in trace.rounds[0].tenants]
    tokens = {f"tok-{t}": t for t in tenants}
    store = UpdateStore()
    svc = _mk_service(store, timeout=20.0)
    with EdgeAggregatorServer(svc, tokens, max_running=2) as edge:
        writers = [
            start_writer(
                None, tr, seed,
                writer=HttpStoreClient(
                    "127.0.0.1", edge.port, token=f"tok-{tr.tenant}"
                ).write,
            )
            for tr in trace.rounds[0].tenants
        ]
        results = edge.run_rounds(tenants, expected_clients=n)
        for w in writers:
            w.join(timeout=30)
    for tr in trace.rounds[0].tenants:
        fused, rep = results[tr.tenant]
        assert rep.n_clients == n
        u = np.stack([trace_payload(seed, tr.tenant, ev.client_id, p)
                      for ev in tr.events])
        w = np.asarray([ev.weight for ev in tr.events], np.float32)
        ref = np.einsum("np,n->p", u, w) / (w.sum() + 1e-6)
        assert np.allclose(np.asarray(fused), ref, rtol=1e-5,
                           atol=1e-5), tr.tenant
    assert len(edge.scheduler.admission_order()) == k


# -- benchmark smoke (tier-1 wiring) -----------------------------------------

def test_ingest_benchmark_quick_smoke(tmp_path):
    """The --quick ingest bench must hold its full acceptance bundle:
    every upload lands exactly once under mid-run disconnects, rounds
    are formula-exact, p50/p99 are reported."""
    out = tmp_path / "BENCH_ingest.json"
    r = subprocess.run(
        [sys.executable,
         os.path.join(REPO, "benchmarks", "ingest_service.py"),
         "--quick", "--out", str(out)],
        capture_output=True, text=True, timeout=280,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
    )
    assert r.returncode == 0, r.stdout + r.stderr
    payload = json.loads(out.read_text())
    assert payload["acceptance"] is True, payload
    up = payload["uploads"]
    assert up["accepted"] == up["total"]
    assert up["disconnects_injected"] > 0
    assert 0 < up["p50_latency_s"] <= up["p99_latency_s"]
    assert all(payload["rounds_exact"].values())
