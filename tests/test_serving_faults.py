"""Fault injection against the ingest front-end.

Three failure families, each with the same invariant — a failed upload
lands NOTHING, a recovered server loses NOTHING:

  * mid-upload disconnect (FIN short of Content-Length): counted, no
    registration, the client's retry lands exactly once;
  * slow-loris (stalled body): the read timeout converts a pinned
    handler thread into a 408;
  * front-end kill + restart over a DISK spool: a fresh ``UpdateStore``
    recovers every committed update (weights, counts, tenant bytes)
    with no duplicates and no phantoms, and serving resumes.
"""
import os
import socket
import threading
import time

import numpy as np
import pytest

from repro.core import AggregationService, UpdateStore
from repro.serving import HttpStoreClient, IngestServer, encode_update

TOKENS = {"tok-a": "appa", "tok-b": "appb"}


def _partial_upload(port, token, body, fraction=0.5):
    """Send the request head declaring the FULL Content-Length, then
    only ``fraction`` of the body, then FIN (a deterministic mid-upload
    disconnect — RST can destroy buffered-but-unread bytes and race the
    accept, hiding the request from the server entirely)."""
    cut = max(1, int(len(body) * fraction))
    head = (
        f"POST /v1/upload HTTP/1.1\r\n"
        f"Host: 127.0.0.1\r\n"
        f"Authorization: Bearer {token}\r\n"
        f"Content-Length: {len(body)}\r\n\r\n"
    ).encode()
    s = socket.create_connection(("127.0.0.1", port), timeout=5.0)
    try:
        s.sendall(head + body[:cut])
    finally:
        s.close()


def _wait_metric(srv, name, at_least, deadline_s=10.0):
    deadline = time.monotonic() + deadline_s
    while time.monotonic() < deadline:
        if srv.metrics().get(name, 0) >= at_least:
            return True
        time.sleep(0.02)
    return False


# -- mid-upload disconnect ---------------------------------------------------

def test_mid_upload_disconnect_lands_nothing_then_retry_lands_once():
    store = UpdateStore()
    vec = np.arange(2000, dtype=np.float32)
    body = encode_update("c0", vec, weight=2.0)
    with IngestServer(store, TOKENS) as srv:
        for frac in (0.1, 0.5, 0.9):
            _partial_upload(srv.port, "tok-a", body, fraction=frac)
        assert _wait_metric(srv, "disconnect", 3), srv.metrics()
        assert store.count() == 0, "a torn upload landed a blob"
        # the client's retry lands the update exactly once
        cli = HttpStoreClient("127.0.0.1", srv.port, token="tok-a")
        cli.write("c0", vec, weight=2.0, tenant="appa")
        assert store.count(tenant="appa") == 1
        got, w = store.read("c0", tenant="appa")
        assert w == 2.0 and np.array_equal(np.asarray(got), vec)
        assert srv.metrics().get("accepted") == 1


def test_disconnect_even_mid_header_does_not_wedge_the_server():
    store = UpdateStore()
    with IngestServer(store, TOKENS) as srv:
        for _ in range(4):
            s = socket.create_connection(("127.0.0.1", srv.port),
                                         timeout=5.0)
            s.sendall(b"POST /v1/upload HT")   # torn mid-request-line
            s.close()
        # server must still serve real uploads afterwards
        cli = HttpStoreClient("127.0.0.1", srv.port, token="tok-a")
        cli.write("c1", np.ones(32, np.float32), tenant="appa")
        assert store.count(tenant="appa") == 1


# -- slow-loris --------------------------------------------------------------

def test_slow_loris_body_stall_times_out_with_408():
    store = UpdateStore()
    body = encode_update("c0", np.ones(4000, np.float32))
    with IngestServer(store, TOKENS, read_timeout=0.3) as srv:
        head = (
            f"POST /v1/upload HTTP/1.1\r\n"
            f"Host: 127.0.0.1\r\n"
            f"Authorization: Bearer tok-a\r\n"
            f"Content-Length: {len(body)}\r\n\r\n"
        ).encode()
        s = socket.create_connection(("127.0.0.1", srv.port),
                                     timeout=10.0)
        try:
            s.sendall(head + body[:64])   # ...then stall, socket open
            t0 = time.monotonic()
            resp = s.recv(4096)           # server must give up first
            waited = time.monotonic() - t0
        finally:
            s.close()
        assert b"408" in resp.split(b"\r\n", 1)[0], resp
        assert waited < 5.0, "read timeout did not bound the stall"
        assert srv.metrics().get("read_timeout") == 1
        assert store.count() == 0
        # the handler thread was reclaimed; serving continues
        cli = HttpStoreClient("127.0.0.1", srv.port, token="tok-a")
        cli.write("c0", np.ones(8, np.float32), tenant="appa")
        assert store.count(tenant="appa") == 1


def test_slow_loris_does_not_block_other_tenants():
    """A stalled upload must not head-of-line block concurrent
    uploads (threaded handlers + per-connection timeouts)."""
    store = UpdateStore()
    body = encode_update("c0", np.ones(4000, np.float32))
    with IngestServer(store, TOKENS, read_timeout=2.0) as srv:
        head = (
            f"POST /v1/upload HTTP/1.1\r\nHost: x\r\n"
            f"Authorization: Bearer tok-a\r\n"
            f"Content-Length: {len(body)}\r\n\r\n"
        ).encode()
        s = socket.create_connection(("127.0.0.1", srv.port),
                                     timeout=10.0)
        try:
            s.sendall(head + body[:16])   # stall appa's upload
            t0 = time.monotonic()
            cli = HttpStoreClient("127.0.0.1", srv.port, token="tok-b")
            cli.write("b0", np.ones(64, np.float32), tenant="appb")
            elapsed = time.monotonic() - t0
        finally:
            s.close()
        assert elapsed < 1.0, "stalled upload blocked a healthy one"
        assert store.count(tenant="appb") == 1


# -- kill / restart recovery -------------------------------------------------

def test_frontend_restart_recovers_spool_without_dup_or_phantom(tmp_path):
    n, p = 6, 500
    rng = np.random.default_rng(3)
    payloads = {f"c{i}": rng.normal(size=(p,)).astype(np.float32)
                for i in range(n)}
    weights = {f"c{i}": 1.0 + 0.5 * i for i in range(n)}

    store = UpdateStore(backend="disk", spool_dir=str(tmp_path))
    with IngestServer(store, TOKENS) as srv:
        cli = HttpStoreClient("127.0.0.1", srv.port,
                              tokens={"appa": "tok-a", "appb": "tok-b"})
        for cid, vec in payloads.items():
            cli.write(cid, vec, weight=weights[cid], tenant="appa")
        cli.write("b0", np.ones(p, np.float32), tenant="appb")
        # a torn upload right before the "crash": must not resurrect
        _partial_upload(srv.port, "tok-a",
                        encode_update("ghost", np.ones(p, np.float32)))
        assert _wait_metric(srv, "disconnect", 1)
        st = store.stats_for("appa")
        assert st.writes == n
        assert st.bytes_written == sum(
            v.nbytes for v in payloads.values()) * store.replication
        bytes_before = store.tenant_bytes("appa")
    # front-end killed (closed). A FRESH store process recovers the
    # spool:
    store2 = UpdateStore(backend="disk", spool_dir=str(tmp_path))
    assert store2.count(tenant="appa") == n
    assert store2.count(tenant="appb") == 1
    assert sorted(store2.client_ids(tenant="appa")) == sorted(payloads)
    assert "ghost" not in store2.client_ids(tenant="appa")
    assert store2.tenant_bytes("appa") == bytes_before
    for cid, vec in payloads.items():
        got, w = store2.read(cid, tenant="appa")
        assert w == weights[cid]
        assert np.array_equal(np.asarray(got), vec), cid
    # serving resumes on the recovered spool: a round folds exactly the
    # recovered set, and a re-upload REPLACES rather than duplicates
    svc = AggregationService(fusion="fedavg", local_strategy="jnp",
                             store=store2, threshold_frac=1.0,
                             monitor_timeout=5.0)
    with IngestServer(store2, TOKENS) as srv2:
        cli = HttpStoreClient("127.0.0.1", srv2.port, token="tok-a")
        cli.write("c0", payloads["c0"], weight=weights["c0"],
                  tenant="appa")
        assert store2.count(tenant="appa") == n   # replaced, not added
        fused, rep = svc.aggregate(from_store=True, expected_clients=n,
                                   tenant="appa")
    assert rep.n_clients == n
    u = np.stack([payloads[f"c{i}"] for i in range(n)])
    w = np.asarray([weights[f"c{i}"] for i in range(n)], np.float32)
    ref = np.einsum("np,n->p", u, w) / (w.sum() + 1e-6)
    assert np.allclose(np.asarray(fused), ref, rtol=1e-5, atol=1e-5)


def test_restart_preserves_compressed_uploads(tmp_path):
    """Compressed uploads (codes + .scale/.dim sidecars) survive the
    restart with their real (compressed) byte accounting."""
    from repro.core.compress import compress_update

    store = UpdateStore(backend="disk", spool_dir=str(tmp_path))
    vec = np.linspace(-1, 1, 1024).astype(np.float32)
    cu = compress_update(vec, block=256)
    with IngestServer(store, TOKENS) as srv:
        cli = HttpStoreClient("127.0.0.1", srv.port, token="tok-a")
        cli.write("c0", cu, weight=1.0, tenant="appa")
        bytes_before = store.tenant_bytes("appa")
        assert bytes_before < vec.nbytes   # compression bought headroom
    store2 = UpdateStore(backend="disk", spool_dir=str(tmp_path))
    assert store2.count(tenant="appa") == 1
    assert store2.tenant_bytes("appa") == bytes_before
    got, w = store2.read("c0", tenant="appa")
    assert w == 1.0
    # the recovered container is bit-identical to what was uploaded
    assert got.dim == cu.dim
    assert np.array_equal(np.asarray(got.codes), np.asarray(cu.codes))
    assert np.array_equal(np.asarray(got.scales), np.asarray(cu.scales))
