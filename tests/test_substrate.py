"""Substrate tests: optimizer, schedules, data pipeline, checkpoint,
secure masking, HLO analysis, sharding rules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_pytree, save_pytree
from repro.core import SecureMasking
from repro.core.fusion import IterAvg
from repro.core.local import LocalEngine
from repro.data import SyntheticLM, dirichlet_partition, shard_partition
from repro.optim import (
    adamw,
    apply_updates,
    clip_by_global_norm,
    cosine_decay,
    global_norm,
    sgd,
    warmup_cosine,
)
from repro.utils.hlo import analyze_collectives, split_computations
from repro.utils.pytree import (
    flat_vector_to_tree,
    tree_to_flat_vector,
    tree_size_bytes,
)

RNG = np.random.default_rng(11)


# -- optimizers ----------------------------------------------------------------


def test_sgd_descends_quadratic():
    opt = sgd(0.1)
    params = {"x": jnp.asarray(5.0)}
    state = opt.init(params)
    for step in range(100):
        grads = jax.grad(lambda p: 0.5 * p["x"] ** 2)(params)
        ups, state = opt.update(grads, state, jnp.int32(step))
        params = apply_updates(params, ups)
    assert abs(float(params["x"])) < 1e-3


def test_adamw_descends_and_decays():
    opt = adamw(0.1, weight_decay=0.01)
    params = {"x": jnp.asarray(5.0)}
    state = opt.init(params)
    for step in range(200):
        grads = jax.grad(lambda p: 0.5 * p["x"] ** 2)(params)
        ups, state = opt.update(grads, state, jnp.int32(step), params)
        params = apply_updates(params, ups)
    assert abs(float(params["x"])) < 1e-2


def test_clip_by_global_norm():
    tree = {"a": jnp.full((10,), 10.0)}
    clipped = clip_by_global_norm(tree, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_schedules():
    s = warmup_cosine(1.0, warmup_steps=10, total_steps=110)
    assert float(s(jnp.int32(0))) == 0.0
    assert float(s(jnp.int32(10))) == pytest.approx(1.0, rel=1e-5)
    assert float(s(jnp.int32(110))) <= 0.2
    c = cosine_decay(1.0, 100)
    assert float(c(jnp.int32(0))) == pytest.approx(1.0)


# -- data ------------------------------------------------------------------------


def test_synthetic_deterministic_and_learnable_structure():
    g = SyntheticLM(vocab=64, seed=0)
    a = g.sample(2, 16, rng_seed=1)
    b = g.sample(2, 16, rng_seed=1)
    np.testing.assert_array_equal(a, b)
    c = g.sample(2, 16, rng_seed=2)
    assert not np.array_equal(a, c)
    assert a.min() >= 0 and a.max() < 64


def test_dirichlet_partition_covers_all():
    parts = dirichlet_partition(1000, 10, alpha=0.5, seed=0)
    allidx = np.sort(np.concatenate(parts))
    np.testing.assert_array_equal(allidx, np.arange(1000))
    assert all(len(p) >= 1 for p in parts)
    # skewed: client sizes differ substantially at alpha=0.5
    sizes = [len(p) for p in parts]
    assert max(sizes) > 2 * min(sizes)


def test_shard_partition_balanced():
    parts = shard_partition(100, 7)
    sizes = [len(p) for p in parts]
    assert max(sizes) - min(sizes) <= 1


# -- pytree / checkpoint ----------------------------------------------------------


def test_flat_vector_roundtrip():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    vec = tree_to_flat_vector(tree)
    assert vec.shape == (10,)
    back = flat_vector_to_tree(vec, tree)
    for x, y in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        assert x.dtype == y.dtype
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32))


def test_checkpoint_roundtrip(tmp_path):
    tree = {"w": jnp.asarray(RNG.normal(size=(8, 4)), jnp.float32),
            "opt": {"m": jnp.zeros((3,), jnp.bfloat16)},
            "step": jnp.int32(7)}
    path = str(tmp_path / "ckpt")
    save_pytree(path, tree)
    back = load_pytree(path, tree)
    np.testing.assert_allclose(back["w"], tree["w"])
    assert back["opt"]["m"].dtype == jnp.bfloat16
    assert int(back["step"]) == 7


# -- secure aggregation -----------------------------------------------------------


def test_pairwise_masks_cancel_in_sum():
    n, p = 6, 128
    sm = SecureMasking(n_clients=n, seed=9)
    vecs = [jnp.asarray(RNG.normal(size=(p,)), jnp.float32)
            for _ in range(n)]
    masked = [sm.mask_update(i, v) for i, v in enumerate(vecs)]
    np.testing.assert_allclose(
        np.asarray(sum(masked)), np.asarray(sum(vecs)), rtol=1e-4, atol=1e-4
    )


def test_masked_iteravg_equals_unmasked():
    """IterAvg over masked updates == over raw updates (sum-reducible)."""
    n, p = 5, 64
    sm = SecureMasking(n_clients=n, seed=1)
    u = RNG.normal(size=(n, p)).astype(np.float32)
    masked = np.stack(
        [np.asarray(sm.mask_update(i, jnp.asarray(u[i]))) for i in range(n)]
    )
    eng = LocalEngine(strategy="jnp")
    a = np.asarray(eng.fuse(IterAvg(), u, None))
    b = np.asarray(eng.fuse(IterAvg(), masked, None))
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)


def test_single_masked_update_hides_value():
    sm = SecureMasking(n_clients=4, seed=3, scale=10.0)
    v = jnp.zeros((64,), jnp.float32)
    masked = np.asarray(sm.mask_update(0, v))
    assert np.abs(masked).mean() > 1.0  # far from the raw (zero) update


# -- HLO analysis ------------------------------------------------------------------


def test_hlo_while_trip_multiplication():
    """A collective inside a lax.scan body must be counted trip times."""
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.utils.compat import make_mesh
    mesh = make_mesh((1,), ("x",))

    def body(c, _):
        return jax.lax.psum(c, "x"), None

    def f(x):
        out, _ = jax.lax.scan(body, x, None, length=5)
        return out

    from repro.utils.compat import shard_map
    sfn = shard_map(f, mesh=mesh, in_specs=(P(),), out_specs=P(),
                    check_vma=False)
    compiled = jax.jit(sfn).lower(
        jax.ShapeDtypeStruct((128,), jnp.float32)
    ).compile()
    stats = analyze_collectives(compiled.as_text())
    # 5 iterations x one all-reduce (group size 1 -> factor may vary, but
    # the COUNT must reflect the trip count)
    assert stats.counts["all-reduce"] >= 5.0


def test_split_computations_handles_tuple_params():
    hlo = (
        "%comp.1 (p: (s32[], f32[4])) -> (s32[], f32[4]) {\n"
        "  %x = f32[4] add(%a, %b)\n"
        "}\n"
        "ENTRY %main.2 (q: f32[4]) -> f32[4] {\n"
        "  %y = f32[4] multiply(%q, %q)\n"
        "}\n"
    )
    comps = split_computations(hlo)
    assert "comp.1" in comps and "main.2" in comps
