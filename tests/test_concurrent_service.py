"""Concurrent round EXECUTION on one service (ISSUE 5):

  * stress — >=4 tenants' rounds run genuinely concurrently on ONE
    AggregationService for >=20 rounds each (threaded writers + the
    RoundScheduler), every round's fused vector matching the
    isolated-store dense formula and the CompiledCache recording
    exactly one cold compile per shape bucket;
  * CompiledCache single-flight — racing threads on one key compile
    once and share the executable (and a failed build hands the slot
    to a waiter instead of caching the failure);
  * per-tenant quotas — reject raises before any blob lands, evict
    drops the tenant's oldest update (bumping its version) and counts
    into the tenant's StoreStats;
  * the evict-vs-closing-round race — an evicted entry's bumped
    write-version makes the closing round's version-checked remove
    skip its unlink (a re-submitted blob survives) and makes a
    mid-read eviction skip the row instead of folding stale bytes;
  * drift re-warmup — saturated drift for k consecutive rounds forces
    one static "rewarm" round and resets the tenant's EW curve;
  * the --quick benchmark smoke (tier-1 wiring for the scheduler).
"""
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core import (
    AdaptiveController,
    AggregationService,
    QuotaExceededError,
    RoundScheduler,
    UpdateStore,
)
from repro.utils.jitcache import CompiledCache

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RNG = np.random.default_rng(7)


def fedavg_formula(u, w):
    return np.einsum("np,n->p", u, w) / w.sum()


# -- the tentpole stress bar --------------------------------------------------


@pytest.mark.usefixtures("lock_witness")
def test_stress_concurrent_tenants_on_one_service():
    """4 tenants x 20 rounds, all four executing at once on ONE service
    with writers racing the open rounds; per-round fused vectors must
    equal the dense formula on that tenant's round data alone, and the
    shared engine must have cold-compiled exactly once (one shape
    bucket across all tenants and rounds)."""
    k, rounds, n, p = 4, 20, 6, 256
    tenants = [f"app{i}" for i in range(k)]
    u = RNG.normal(size=(k, rounds, n, p)).astype(np.float32)
    w = RNG.uniform(1, 5, size=(k, rounds, n)).astype(np.float32)
    store = UpdateStore()
    svc = AggregationService(
        fusion="fedavg", local_strategy="jnp", store=store,
        threshold_frac=1.0, monitor_timeout=60.0,
    )
    errors = []

    def drive(kk, tenant, sched):
        try:
            for r in range(rounds):
                def write(kk=kk, r=r, tenant=tenant):
                    for i in range(n):
                        store.write(f"c{i}", u[kk, r, i],
                                    weight=float(w[kk, r, i]),
                                    tenant=tenant)
                wt = threading.Thread(target=write, daemon=True)
                wt.start()
                fused, rep = sched.submit(
                    tenant, from_store=True, expected_clients=n,
                    async_round=True,
                ).result(timeout=120)
                wt.join()
                assert rep.n_clients == n, (tenant, r, rep.n_clients)
                ref = fedavg_formula(u[kk, r], w[kk, r])
                np.testing.assert_allclose(
                    np.asarray(fused), ref, rtol=1e-4, atol=1e-5,
                    err_msg=f"{tenant} round {r}",
                )
                # queue semantics: the round consumed its whole fold
                assert store.count(tenant) == 0
        except BaseException as exc:  # surface in the main thread
            errors.append((tenant, exc))

    with RoundScheduler(svc) as sched:
        drivers = [
            threading.Thread(target=drive, args=(kk, t, sched),
                             daemon=True)
            for kk, t in enumerate(tenants)
        ]
        for d in drivers:
            d.start()
        for d in drivers:
            d.join()
    assert not errors, errors
    # one shape bucket -> exactly one cold compile for 4 tenants x 20
    # rounds (the single-flight cache bar: not <= K x buckets)
    assert svc.local.cache.misses == 1
    # per-tenant accounting saw every write
    for t in tenants:
        assert store.stats_for(t).writes == rounds * n
    assert store.stats.writes == k * rounds * n


@pytest.mark.usefixtures("lock_witness")
def test_scheduler_same_tenant_rounds_serialize_fifo():
    store = UpdateStore()
    svc = AggregationService(
        fusion="fedavg", store=store, threshold_frac=1.0,
        monitor_timeout=5.0,
    )
    n, p = 4, 64
    u1, w1 = RNG.normal(size=(n, p)).astype(np.float32), np.ones(n, np.float32)
    u2 = RNG.normal(size=(n, p)).astype(np.float32)
    with RoundScheduler(svc) as sched:
        for i in range(n):
            store.write(f"c{i}", u1[i], tenant="a")
        f1 = sched.submit("a", from_store=True, expected_clients=n,
                          async_round=True)
        fused1, rep1 = f1.result(timeout=60)
        for i in range(n):
            store.write(f"c{i}", u2[i], tenant="a")
        f2 = sched.submit("a", from_store=True, expected_clients=n,
                          async_round=True)
        fused2, rep2 = f2.result(timeout=60)
        assert sched.tenants() == ["a"]
    np.testing.assert_allclose(
        np.asarray(fused1), fedavg_formula(u1, w1), rtol=1e-4, atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(fused2), fedavg_formula(u2, w1), rtol=1e-4, atol=1e-5
    )
    assert sched.submit is not None
    with pytest.raises(RuntimeError):
        sched.submit("a", from_store=True)   # shut down


@pytest.mark.usefixtures("lock_witness")
def test_concurrent_adaptive_rounds_share_controller_safely():
    """Two tenants' adaptive rounds at once: the controller's internal
    lock keeps policy derivation/observation consistent (no exception,
    both tenants end up with their own learned curves)."""
    store = UpdateStore()
    svc = AggregationService(
        fusion="fedavg", store=store, threshold_frac=1.0,
        monitor_timeout=5.0, adaptive=True,
    )
    n, p = 4, 64
    with RoundScheduler(svc) as sched:
        for r in range(3):
            for t in ("a", "b"):
                for i in range(n):
                    store.write(f"c{i}", RNG.normal(size=(p,))
                                .astype(np.float32), tenant=t)
            res = sched.run_round(["a", "b"], from_store=True,
                                  expected_clients=n, async_round=True)
            for t in ("a", "b"):
                assert res[t][1].n_clients == n
    assert set(svc.controller.tenants()) == {"a", "b"}
    assert svc.controller.model("a").rounds == 3


def test_device_concurrency_validates():
    with pytest.raises(ValueError):
        AggregationService(fusion="fedavg", device_concurrency=0)


# -- CompiledCache single-flight ---------------------------------------------


def test_compiled_cache_single_flight_under_race():
    import jax

    cache = CompiledCache("race")
    built = []
    all_started = threading.Event()

    def builder():
        built.append(1)
        # hold the build slot until every racer thread is running, so
        # they genuinely pile up on the in-flight build (event-gated,
        # not a timing-guessed sleep)
        all_started.wait(timeout=10.0)
        return lambda x: x + 1

    results = []

    def hit():
        fn, dt = cache.get(
            ("k",), builder, jax.ShapeDtypeStruct((4,), np.float32)
        )
        results.append((fn, dt))

    threads = [threading.Thread(target=hit) for _ in range(8)]
    for t in threads:
        t.start()
    all_started.set()
    for t in threads:
        t.join()
    assert len(built) == 1          # one build, shared by all racers
    assert cache.misses == 1 and cache.hits == 7
    paid = [dt for _, dt in results if dt > 0.0]
    assert len(paid) == 1           # only the builder paid compile time
    fns = {id(fn) for fn, _ in results}
    assert len(fns) == 1            # everyone shares the executable


def test_compiled_cache_failed_build_releases_slot():
    import jax

    cache = CompiledCache("fail")
    attempts = []

    def flaky():
        attempts.append(1)
        if len(attempts) == 1:
            raise RuntimeError("first build dies")
        return lambda x: x * 2

    spec = jax.ShapeDtypeStruct((2,), np.float32)
    with pytest.raises(RuntimeError):
        cache.get(("k",), flaky, spec)
    fn, dt = cache.get(("k",), flaky, spec)   # slot was released
    assert len(attempts) == 2 and dt > 0.0
    np.testing.assert_allclose(
        np.asarray(fn(np.ones(2, np.float32))), 2.0
    )


# -- per-tenant quotas and stats ---------------------------------------------


def test_quota_reject_raises_and_leaves_partition_intact():
    s = UpdateStore()
    s.set_quota("a", max_bytes=40, policy="reject")
    s.write("c0", np.ones(8, np.float32), tenant="a")   # 32 B: fits
    with pytest.raises(QuotaExceededError):
        s.write("c1", np.ones(8, np.float32), tenant="a")
    assert s.client_ids("a") == ["c0"]
    assert s.tenant_bytes("a") == 32
    # replacing the resident update stays within budget (delta-counted)
    s.write("c0", np.ones(8, np.float32) * 2, tenant="a")
    assert s.client_ids("a") == ["c0"]


def test_quota_reject_on_disk_leaves_no_orphan_blob(tmp_path):
    s = UpdateStore(backend="disk", spool_dir=str(tmp_path))
    s.set_quota("default", max_updates=1, policy="reject")
    s.write("c0", np.ones(4, np.float32))
    with pytest.raises(QuotaExceededError):
        s.write("c1", np.ones(4, np.float32))
    assert not os.path.exists(tmp_path / "c1.npy")


def test_quota_evict_drops_oldest_and_counts():
    s = UpdateStore()
    s.set_quota("a", max_updates=2, policy="evict")
    s.write("c0", np.ones(4, np.float32), tenant="a")
    s.write("c1", np.ones(4, np.float32), tenant="a")
    s.write("c2", np.ones(4, np.float32), tenant="a")
    assert s.client_ids("a") == ["c1", "c2"]   # oldest arrival evicted
    assert s.stats_for("a").evictions == 1
    assert s.stats.evictions == 1
    # an update alone bigger than the byte budget rejects even under
    # evict (nothing to evict for it)
    s.set_quota("b", max_bytes=8, policy="evict")
    with pytest.raises(QuotaExceededError):
        s.write("c0", np.ones(8, np.float32), tenant="b")


def test_quota_does_not_bleed_across_tenants():
    s = UpdateStore()
    s.set_quota("noisy", max_updates=1, policy="evict")
    for i in range(5):
        s.write(f"c{i}", np.ones(4, np.float32), tenant="noisy")
        s.write(f"c{i}", np.ones(4, np.float32), tenant="quiet")
    assert s.count("noisy") == 1
    assert s.count("quiet") == 5
    assert s.stats_for("quiet").evictions == 0


def test_round_report_carries_tenant_store_stats():
    store = UpdateStore()
    svc = AggregationService(
        fusion="fedavg", store=store, threshold_frac=1.0,
        monitor_timeout=2.0,
    )
    n, p = 4, 64
    for i in range(n):
        store.write(f"c{i}", RNG.normal(size=(p,)).astype(np.float32),
                    tenant="a")
        store.write(f"x{i}", RNG.normal(size=(p,)).astype(np.float32),
                    tenant="b")
    _, rep = svc.aggregate(from_store=True, expected_clients=n,
                           tenant="a")
    assert rep.store_stats is not None
    assert rep.store_stats.writes == n          # tenant a's alone
    assert rep.store_stats.reads == n
    assert store.stats.writes == 2 * n          # legacy aggregate view


# -- evict vs closing round --------------------------------------------------


def test_eviction_version_bump_defeats_stale_unlink(tmp_path):
    """The PR-4 race, deterministically: a round folded c0 at version 1;
    c0 is then evicted and re-submitted (version moves on). The closing
    round's version-checked remove must NOT unlink the successor."""
    s = UpdateStore(backend="disk", spool_dir=str(tmp_path))
    s.write("c0", np.ones(4, np.float32))
    folded_versions = {"c0": s._versions[("default", "c0")]}
    # eviction (what quota pressure or a re-submission does)
    with s._lock:
        s._evict_locked(("default", "c0"))
    s.write("c0", np.ones(4, np.float32) * 3)   # the re-submission
    s.remove(["c0"], versions=folded_versions)  # the round's close
    assert os.path.exists(tmp_path / "c0.npy")  # successor survived
    u, w = s.read("c0")
    np.testing.assert_allclose(u, 3.0)


def test_victim_rewritten_after_eviction_keeps_fresh_blob(tmp_path):
    """A quota-eviction victim re-written between the eviction and the
    evictor's unlink must keep its FRESH blob: the unlink re-checks the
    version recorded at eviction (the remove() guard, reused)."""
    s = UpdateStore(backend="disk", spool_dir=str(tmp_path))
    s.set_quota("default", max_updates=1, policy="evict")
    s.write("c0", np.ones(4, np.float32))
    with s._lock:   # the eviction half of an in-flight write("c1")
        verdict, victims = s._quota_check_locked(("default", "c1"), 16)
    assert verdict == "ok" and list(victims) == [("default", "c0")]
    s.write("c0", np.ones(4, np.float32) * 7)   # re-write races the unlink
    s._unlink_evicted(victims)                  # ...which must now no-op
    assert os.path.exists(tmp_path / "c0.npy")
    u, _ = s.read("c0")
    np.testing.assert_allclose(u, 7.0)


def test_mid_read_eviction_skips_row_instead_of_folding(tmp_path,
                                                        monkeypatch):
    """A streaming read that races an eviction must DISCARD the stale
    bytes (half-unlinked blob), not fold them: the eviction bumps the
    version before touching files, and _read_versioned re-checks after
    the blob read."""
    s = UpdateStore(backend="disk", spool_dir=str(tmp_path))
    s.write("c0", np.ones(4, np.float32))
    s.write("c1", np.ones(4, np.float32) * 2)
    orig = UpdateStore._sidecar_dtype
    evicted = []

    def evict_mid_read(path):
        # fires between the blob read and the version re-check
        if path.endswith("c0.npy") and not evicted:
            with s._lock:
                s._evict_locked(("default", "c0"))
            evicted.append(True)
        return orig(path)

    monkeypatch.setattr(UpdateStore, "_sidecar_dtype",
                        staticmethod(evict_mid_read))
    with s._lock:
        keys = s._keys("default")
    blk = s._load_block(keys)
    assert evicted
    (block, w, loaded), = blk                   # one dense sub-block
    assert block.shape[0] == 1                  # c0's row was skipped
    np.testing.assert_allclose(block[0], 2.0)   # only c1 folded
    assert loaded == [("default", "c1")]


# -- drift-triggered re-warmup ------------------------------------------------


def test_drift_saturation_forces_rewarm_and_resets_curve():
    c = AdaptiveController(
        threshold_frac=1.0, timeout=10.0,
        rewarm_drift=0.5, rewarm_patience=2,
    )
    for _ in range(3):   # steady regime
        c.observe_round("t", [0.1 * i for i in range(1, 11)], 10)
    assert c.policy("t", 10).source == "learned"
    # regime change the EW window cannot catch: drift saturates
    for r in range(3):
        c.observe_round(
            "t", [5.0 + 30 * r + 0.3 * i for i in range(1, 11)], 10
        )
    assert c.model("t").drift >= 0.5
    pol = c.policy("t", 10)
    assert pol.source == "rewarm"
    assert pol.deadline == 10.0                 # the static gate
    assert c.model("t").rounds == 0             # EW curve reset
    # next policy is NOT a prior borrow (the prior carries the stale
    # regime): static until the fresh curve warms up
    assert c.policy("t", 10).source == "static"
    c.observe_round("t", [0.1 * i for i in range(1, 11)], 10)
    assert c.policy("t", 10).source == "learned"   # re-learned


def test_rewarm_state_survives_checkpoint_roundtrip():
    c = AdaptiveController(rewarm_drift=0.5, rewarm_patience=2)
    for r in range(5):
        c.observe_round(
            "t", [1.0 + 30 * r + 0.2 * i for i in range(1, 9)], 8
        )
    state = c.state_dict()
    c2 = AdaptiveController(rewarm_drift=0.5, rewarm_patience=2)
    c2.load_state_dict(state)
    assert c2.policy("t", 8).source == c.policy("t", 8).source


def test_steady_drift_never_triggers_rewarm():
    c = AdaptiveController(rewarm_drift=0.5, rewarm_patience=2)
    for _ in range(10):
        c.observe_round("t", [0.1 * i for i in range(1, 9)], 8)
    assert c.policy("t", 8).source == "learned"


# -- tier-1 wiring for the scheduler benchmark --------------------------------


def test_concurrent_benchmark_quick_smoke(tmp_path):
    """The --quick benchmark is the scheduler's end-to-end regression
    gate: concurrent-on-one-service must beat K serialized rounds with
    full inclusion, formula-equivalent vectors, and cold compiles
    bounded by shape buckets — in tier-1, not only in full runs."""
    import json

    out = tmp_path / "BENCH_concurrent.json"
    r = subprocess.run(
        [sys.executable,
         os.path.join(REPO, "benchmarks", "concurrent_service.py"),
         "--quick", "--out", str(out)],
        capture_output=True, text=True, timeout=280,
        env={**os.environ,
             "PYTHONPATH": os.path.join(REPO, "src")},
    )
    assert r.returncode == 0, r.stdout + r.stderr
    payload = json.loads(out.read_text())
    assert payload["acceptance"] is True, payload
    assert payload["results"]["concurrent"]["cold_compiles"] <= \
        payload["shape_buckets"]
