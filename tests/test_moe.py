"""MoE execution-path equivalence: scatter (meshless) == dense-mix
(decode) == shard_map all-to-all (meshed), plus routing invariants."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.layers.moe import (
    _capacity,
    _moe_dense_mix,
    _moe_scatter,
    _positions_in_expert,
    init_moe,
    moe,
)

RNG = np.random.default_rng(13)


def _setup(E=4, d=32, ff=64, shared=1):
    p = init_moe(jax.random.PRNGKey(0), d, ff, E, shared, jnp.float32)
    x = jnp.asarray(RNG.normal(size=(4, 16, d)) * 0.5, jnp.float32)
    return p, x


def test_scatter_equals_dense_mix_at_high_capacity():
    p, x = _setup()
    o1, a1 = _moe_scatter(p, x, 2, 8.0)   # cf=8: no drops
    o2, a2 = _moe_dense_mix(p, x, 2)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)


def test_moe_grads_flow():
    p, x = _setup()

    def loss(p_):
        o, aux = moe(p_, x, 2, 1.25)
        return jnp.sum(o * o) + 0.01 * aux

    g = jax.grad(loss)(p)
    for leaf in jax.tree_util.tree_leaves(g):
        assert bool(jnp.all(jnp.isfinite(leaf)))
    # router must receive gradient (through gate values)
    assert float(jnp.sum(jnp.abs(g.router))) > 0


def test_positions_in_expert_are_dense_ranks():
    idx = jnp.asarray([2, 0, 2, 1, 0, 2], jnp.int32)
    pos = np.asarray(_positions_in_expert(idx, 3))
    # per expert, ranks are 0..count-1 in order of appearance
    assert pos.tolist() == [0, 0, 1, 0, 1, 2]


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 300), k=st.integers(1, 4), E=st.integers(2, 16),
       cf=st.floats(0.5, 4.0))
def test_capacity_bounds(n, k, E, cf):
    c = _capacity(n, k, E, cf)
    assert c % 8 == 0
    assert c >= min(8, n * k)
    # never more than the 8-rounded total assignment count
    assert c <= -(-max(n * k, 8) // 8) * 8


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    import jax.numpy as jnp
    from repro.models.layers.moe import init_moe, moe, _moe_scatter
    from repro.models.sharding import AxisRules, use_rules
    E, d, ff, k = 4, 32, 64, 2
    p = init_moe(jax.random.PRNGKey(0), d, ff, E, 1, jnp.float32)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 16, d)) * 0.5, jnp.float32)
    o_ref, _ = _moe_scatter(p, x, k, 8.0)
    from repro.utils.compat import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    rules = AxisRules(mesh=mesh, rules={"batch": ("data",),
                                        "seq": ("model",),
                                        "expert": ("model",)})
    with use_rules(rules):
        o_a2a, _ = jax.jit(lambda x: moe(p, x, k, 8.0))(x)
    assert np.allclose(np.asarray(o_a2a), np.asarray(o_ref),
                       rtol=2e-4, atol=2e-5)
    print("MOE_A2A_OK")
""")


def test_a2a_path_matches_scatter_subprocess():
    r = subprocess.run(
        [sys.executable, "-c", _SUBPROC],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"},
    )
    assert "MOE_A2A_OK" in r.stdout, r.stderr[-2000:]
