"""Property tests for the upload wire format (``repro.serving.protocol``).

Round-trips must be lossless for every whitelisted dtype and every
degenerate geometry; everything else — any truncation point, trailing
bytes, corrupted header fields, non-finite numbers, untileable block
geometry — must raise :class:`WireError` before an update object
exists. Runs under real hypothesis when installed, else the
deterministic fallback conftest registers."""
import struct

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.compress import CompressedUpdate, compress_update
from repro.serving import WireError, encode_update, parse_update
from repro.serving.protocol import (
    KIND_COMPRESSED,
    KIND_DENSE,
    MAGIC,
    MAX_CLIENT_ID_BYTES,
)


# -- lossless round-trips ----------------------------------------------------

@settings(max_examples=40)
@given(
    dim=st.integers(min_value=1, max_value=400),
    weight=st.floats(min_value=1e-3, max_value=1e3),
    dtype=st.sampled_from(["float32", "float16", "float64"]),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_dense_round_trip_is_bitwise(dim, weight, dtype, seed):
    vec = np.random.default_rng(seed).normal(size=(dim,)).astype(dtype)
    parsed = parse_update(encode_update("client-7", vec, weight=weight))
    assert parsed.client_id == "client-7"
    assert parsed.weight == weight          # f64 on the wire: exact
    assert parsed.kind == KIND_DENSE
    assert parsed.update.dtype == np.dtype(dtype)
    assert parsed.update.tobytes() == vec.tobytes()


def test_bfloat16_round_trip_is_bitwise():
    import jax.numpy as jnp

    bf16 = np.dtype(jnp.bfloat16)
    vec = np.linspace(-2, 2, 129).astype(bf16)
    parsed = parse_update(encode_update("bf", vec))
    assert parsed.update.dtype == bf16
    assert parsed.update.tobytes() == vec.tobytes()


@settings(max_examples=40)
@given(
    dim=st.integers(min_value=1, max_value=2000),
    block=st.sampled_from([32, 64, 256]),
    weight=st.floats(min_value=1e-3, max_value=1e3),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_compressed_round_trip_is_bitwise(dim, block, weight, seed):
    vec = np.random.default_rng(seed).normal(size=(dim,)) \
        .astype(np.float32)
    cu = compress_update(vec, block=min(block, max(dim, 1)))
    parsed = parse_update(encode_update("cmp", cu, weight=weight))
    assert parsed.kind == KIND_COMPRESSED
    got = parsed.update
    assert isinstance(got, CompressedUpdate)
    assert got.dim == cu.dim and got.block == cu.block
    assert np.array_equal(got.codes, np.asarray(cu.codes, np.int8))
    assert np.array_equal(got.scales,
                          np.asarray(cu.scales, np.float32))


@pytest.mark.parametrize("dim", [1, 2, 255, 256, 257, 511, 512, 513])
def test_compressed_degenerate_dims_round_trip(dim):
    """Block-boundary dims (the ragged-final-block cases)."""
    vec = np.linspace(-1, 1, dim).astype(np.float32)
    cu = compress_update(vec, block=256)
    got = parse_update(encode_update("c", cu)).update
    assert got.dim == dim
    assert np.array_equal(got.codes, np.asarray(cu.codes, np.int8))


def test_unicode_client_id_round_trips():
    vec = np.ones(4, np.float32)
    cid = "edge-αβγ-端末-7"
    assert parse_update(encode_update(cid, vec)).client_id == cid


def test_dim_one_dense_round_trips():
    parsed = parse_update(
        encode_update("c", np.asarray([3.25], np.float32)))
    assert parsed.update.shape == (1,)
    assert parsed.update[0] == np.float32(3.25)


# -- truncation: EVERY proper prefix must fail closed ------------------------

def _frames():
    dense = encode_update("cli-0", np.arange(9, dtype=np.float32),
                          weight=2.0)
    cu = compress_update(np.linspace(-1, 1, 70).astype(np.float32),
                         block=32)
    compressed = encode_update("cli-1", cu, weight=0.5)
    return {"dense": dense, "compressed": compressed}


@pytest.mark.parametrize("name", ["dense", "compressed"])
def test_every_truncation_point_fails_closed(name):
    frame = _frames()[name]
    for cut in range(len(frame)):
        with pytest.raises(WireError):
            parse_update(frame[:cut])


@pytest.mark.parametrize("name", ["dense", "compressed"])
@pytest.mark.parametrize("junk", [b"\x00", b"FLU1", b"\xff" * 9])
def test_trailing_bytes_fail_closed(name, junk):
    frame = _frames()[name]
    with pytest.raises(WireError, match="trailing"):
        parse_update(frame + junk)


# -- corrupted headers -------------------------------------------------------

def test_bad_magic_rejected():
    frame = _frames()["dense"]
    with pytest.raises(WireError, match="magic"):
        parse_update(b"XLU1" + frame[4:])


def test_unknown_kind_rejected():
    frame = bytearray(_frames()["dense"])
    frame[4] = 9
    with pytest.raises(WireError, match="kind"):
        parse_update(bytes(frame))


def test_zero_idlen_rejected():
    frame = bytearray(_frames()["dense"])
    frame[5:7] = struct.pack("<H", 0)
    with pytest.raises(WireError, match="id length"):
        parse_update(bytes(frame))


def test_non_utf8_client_id_rejected():
    head = struct.pack("<4sBH", MAGIC, KIND_DENSE, 2)
    rest = _frames()["dense"][7 + 5:]     # skip original 5-byte id
    with pytest.raises(WireError, match="utf-8"):
        parse_update(head + b"\xff\xfe" + rest)


@pytest.mark.parametrize("w", [0.0, -1.0, float("nan"), float("inf")])
def test_non_positive_or_non_finite_weight_rejected(w):
    # craft on the wire — encode_update refuses to build these
    frame = bytearray(_frames()["dense"])
    off = struct.calcsize("<4sBH") + len("cli-0")
    frame[off:off + 8] = struct.pack("<d", w)
    with pytest.raises(WireError, match="weight"):
        parse_update(bytes(frame))


def test_dtype_off_whitelist_rejected():
    # splice "int64" over the frame's dtype name (same length as
    # "float32"? no — rebuild the dense tail with a forbidden name)
    cid = b"c"
    head = struct.pack("<4sBH", MAGIC, KIND_DENSE, len(cid))
    name = b"int32"
    tail = struct.pack("<B", len(name)) + name + struct.pack("<Q", 2) \
        + np.zeros(2, np.int32).tobytes()
    with pytest.raises(WireError, match="whitelist"):
        parse_update(head + cid + struct.pack("<d", 1.0) + tail)


def test_zero_dim_dense_rejected():
    cid = b"c"
    head = struct.pack("<4sBH", MAGIC, KIND_DENSE, len(cid))
    name = b"float32"
    tail = struct.pack("<B", len(name)) + name + struct.pack("<Q", 0)
    with pytest.raises(WireError, match="dim"):
        parse_update(head + cid + struct.pack("<d", 1.0) + tail)


@settings(max_examples=30)
@given(
    dim=st.integers(min_value=1, max_value=500),
    nblocks=st.integers(min_value=1, max_value=8),
    block=st.integers(min_value=1, max_value=128),
)
def test_untileable_block_geometry_rejected(dim, nblocks, block):
    """Whenever (nblocks, block) does not tile dim the frame must be
    rejected even with a correctly-sized payload; whenever it does,
    the frame parses."""
    cid = b"g"
    head = struct.pack("<4sBH", MAGIC, KIND_COMPRESSED, len(cid))
    frame = (
        head + cid + struct.pack("<d", 1.0)
        + struct.pack("<QII", dim, nblocks, block)
        + np.zeros(nblocks * block, np.int8).tobytes()
        + np.ones(nblocks, np.float32).tobytes()
    )
    tiles = (nblocks - 1) * block < dim <= nblocks * block
    if tiles:
        assert parse_update(frame).update.dim == dim
    else:
        with pytest.raises(WireError, match="geometry"):
            parse_update(frame)


def test_non_finite_scales_rejected():
    cu = compress_update(np.ones(64, np.float32), block=32)
    frame = bytearray(encode_update("c", cu))
    # scales are the final nblocks * 4 bytes
    frame[-8:-4] = struct.pack("<f", float("inf"))
    with pytest.raises(WireError, match="finite"):
        parse_update(bytes(frame))


# -- encode-side refusals ----------------------------------------------------

def test_encode_rejects_bad_client_ids():
    vec = np.ones(4, np.float32)
    with pytest.raises(WireError):
        encode_update("", vec)
    with pytest.raises(WireError):
        encode_update("x" * (MAX_CLIENT_ID_BYTES + 1), vec)
    # multi-byte utf-8 counts in BYTES, not characters
    with pytest.raises(WireError):
        encode_update("端" * 100, vec)   # 300 bytes


def test_encode_rejects_bad_payloads():
    with pytest.raises(WireError, match="1-D"):
        encode_update("c", np.ones((2, 2), np.float32))
    with pytest.raises(WireError, match="1-D"):
        encode_update("c", np.ones(0, np.float32))
    with pytest.raises(WireError, match="whitelist"):
        encode_update("c", np.ones(4, np.int64))
    with pytest.raises(WireError, match="weight"):
        encode_update("c", np.ones(4, np.float32), weight=0.0)
    with pytest.raises(WireError, match="weight"):
        encode_update("c", np.ones(4, np.float32),
                      weight=float("nan"))
