"""Streaming aggregation pipeline + shape-bucketed compile caches.

Covers the tentpole invariants:
  * streamed-chunk == dense-fuse for every reducible fusion at ragged
    sizes (n and P not tile multiples), both engine strategies;
  * a second round whose client count lands in the same power-of-two
    bucket triggers ZERO new jit traces (local dense, local stream, and
    the distributed engine's cached shard_map closures);
  * aggregating from the store never materializes the dense (n, P)
    matrix on the host — peak ingest allocation is O(chunk * P);
  * the pad-free Pallas kernel performs no jnp.pad copy on ragged shapes;
  * the store preserves stored dtype and stays consistent under
    concurrent writers.
"""
from unittest import mock

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AggregationService, LocalEngine, UpdateStore
from repro.core.distributed import DistributedEngine
from repro.core.fusion import REGISTRY, get_fusion
from repro.kernels.fused_fusion.kernel import weighted_sum_pallas
from repro.utils import jitcache
from repro.utils.compat import make_mesh

RNG = np.random.default_rng(11)

REDUCIBLE = sorted(
    name for name, cls in REGISTRY.items() if cls().reducible
)


def _blocks(u, w, chunk):
    for lo in range(0, u.shape[0], chunk):
        yield u[lo:lo + chunk], w[lo:lo + chunk]


# -- streamed == dense --------------------------------------------------------


@pytest.mark.parametrize("name", REDUCIBLE)
@pytest.mark.parametrize("strategy", ["jnp", "pallas"])
@pytest.mark.parametrize("n,p,chunk", [(13, 257, 4), (7, 301, 7), (9, 33, 2)])
def test_stream_matches_dense_ragged(name, strategy, n, p, chunk):
    u = RNG.normal(size=(n, p)).astype(np.float32)
    w = RNG.uniform(1, 5, size=(n,)).astype(np.float32)
    dense = np.asarray(LocalEngine(strategy="jnp").fuse(get_fusion(name), u, w))
    eng = LocalEngine(strategy=strategy)
    streamed, rep = eng.fuse_stream(get_fusion(name), _blocks(u, w, chunk))
    np.testing.assert_allclose(streamed, dense, rtol=1e-4, atol=1e-5)
    assert rep.n_rows == n and rep.chunk_rows == chunk
    assert rep.n_blocks == -(-n // chunk)


def test_stream_rejects_non_streamable():
    u = RNG.normal(size=(6, 16)).astype(np.float32)
    w = np.ones(6, np.float32)
    with pytest.raises(ValueError, match="not streamable"):
        LocalEngine().fuse_stream(get_fusion("krum"), _blocks(u, w, 2))


def test_carve_stream_needs_n_hint():
    """Order-statistic streams size their top-k carve buffers from the
    expected client count — without it the stream must refuse."""
    u = RNG.normal(size=(6, 16)).astype(np.float32)
    w = np.ones(6, np.float32)
    with pytest.raises(ValueError, match="n_hint"):
        LocalEngine().fuse_stream(get_fusion("coordmedian"), _blocks(u, w, 2))


def test_stream_bf16_blocks_match_fp32_reference():
    """The store keeps bf16 updates at 2 bytes; the streamed accumulator
    is still fp32."""
    n, p = 12, 515
    u32 = RNG.normal(size=(n, p)).astype(np.float32)
    u16 = np.asarray(jnp.asarray(u32).astype(jnp.bfloat16))
    w = RNG.uniform(1, 3, size=(n,)).astype(np.float32)
    fused, _ = LocalEngine().fuse_stream(
        get_fusion("fedavg"), _blocks(u16, w, 5)
    )
    ref = np.asarray(LocalEngine().fuse(get_fusion("fedavg"), u32, w))
    np.testing.assert_allclose(np.asarray(fused), ref, rtol=2e-2, atol=2e-2)
    assert np.asarray(fused).dtype == np.float32


# -- shape-bucketed cache: zero re-traces -------------------------------------


@pytest.mark.parametrize("strategy", ["jnp", "pallas"])
def test_dense_bucket_cache_no_retrace(strategy):
    """n=11 and n=13 share the 16-bucket: one executable, zero new traces
    on the second round."""
    eng = LocalEngine(strategy=strategy)
    f = get_fusion("fedavg")
    p = 515
    out = {}
    for n in (11, 13):
        u = RNG.normal(size=(n, p)).astype(np.float32)
        w = RNG.uniform(1, 5, size=(n,)).astype(np.float32)
        before = jitcache.trace_count()
        out[n] = np.asarray(eng.fuse(f, u, w))
        ref = np.einsum("np,n->p", u, w) / (w.sum() + 1e-6)
        np.testing.assert_allclose(out[n], ref, rtol=1e-4, atol=1e-5)
        if n == 11:
            assert jitcache.trace_count() > before  # cold: traced
            assert eng.last_compile_seconds > 0.0
        else:
            assert jitcache.trace_count() == before, "same-bucket re-trace"
            assert eng.last_compile_seconds == 0.0
    assert eng.is_warm(f, 16, p, np.float32)
    assert not eng.is_warm(f, 17, p, np.float32)  # next bucket is cold


def test_stream_step_cache_no_retrace():
    eng = LocalEngine(strategy="pallas")
    f = get_fusion("fedavg")
    n, p, chunk = 19, 257, 8
    u = RNG.normal(size=(n, p)).astype(np.float32)
    w = RNG.uniform(1, 5, size=(n,)).astype(np.float32)
    eng.fuse_stream(f, _blocks(u, w, chunk))
    assert eng.is_warm_stream(f, chunk, p, np.float32)
    before = jitcache.trace_count()
    fused, rep = eng.fuse_stream(f, _blocks(u[:14], w[:14], chunk))
    assert jitcache.trace_count() == before
    assert rep.compile_seconds == 0.0
    ref = np.einsum("np,n->p", u[:14], w[:14]) / (w[:14].sum() + 1e-6)
    np.testing.assert_allclose(np.asarray(fused), ref, rtol=1e-4, atol=1e-5)


def test_distributed_bucket_cache_no_retrace():
    mesh = make_mesh((1, 1), ("data", "model"))
    eng = DistributedEngine(mesh=mesh)
    f = get_fusion("fedavg")
    p = 257
    for i, n in enumerate((11, 13)):
        u = RNG.normal(size=(n, p)).astype(np.float32)
        w = RNG.uniform(1, 5, size=(n,)).astype(np.float32)
        before = jitcache.trace_count()
        fused = np.asarray(eng.fuse(f, u, w))
        ref = np.einsum("np,n->p", u, w) / (w.sum() + 1e-6)
        np.testing.assert_allclose(fused, ref, rtol=1e-4, atol=1e-5)
        if i:
            assert jitcache.trace_count() == before, "same-bucket re-trace"
    assert eng.is_warm(f, 16, p, np.float32)


def test_memory_capped_scan_cache_no_retrace():
    """The capped path is one scanned executable, reused across rounds."""
    f = get_fusion("fedavg")
    p = 100
    eng = LocalEngine(strategy="jnp", memory_cap_bytes=3 * p * 4)
    for i, n in enumerate((13, 15)):
        u = RNG.normal(size=(n, p)).astype(np.float32)
        w = RNG.uniform(1, 5, size=(n,)).astype(np.float32)
        before = jitcache.trace_count()
        fused = np.asarray(eng.fuse(f, u, w))
        ref = np.einsum("np,n->p", u, w) / (w.sum() + 1e-6)
        np.testing.assert_allclose(fused, ref, rtol=1e-4, atol=1e-5)
        if i:
            assert jitcache.trace_count() == before


# -- pad-free pallas kernel ---------------------------------------------------


def test_pallas_ragged_no_full_matrix_pad():
    """Ragged (n, P) must be masked inside the kernel, not jnp.pad-copied.
    (The interpreter may pad single TILES at block boundaries — that's
    O(tile), fine; what must never happen is a pad of the whole matrix.)"""
    import traceback

    n, p = 29, 519
    u = jnp.asarray(RNG.normal(size=(n, p)).astype(np.float32))
    w = jnp.asarray(RNG.uniform(1, 4, size=(n,)).astype(np.float32))
    real_pad = jax.numpy.pad
    our_pads = []

    def spy_pad(operand, *args, **kwargs):
        # jax-internal pads (the interpreter pads blocks on CPU; real TPU
        # DMA clamps instead) are not ours — attribute by call site
        stack = "".join(traceback.format_stack(limit=12))
        if "repro/kernels" in stack or "repro/core" in stack:
            our_pads.append(np.shape(operand))
        return real_pad(operand, *args, **kwargs)

    with mock.patch.object(jax.numpy, "pad", side_effect=spy_pad):
        # fresh shape + tiles => forces a trace through the wsum path
        out = weighted_sum_pallas(u, w, param_tile=256, client_tile=8)
    assert not our_pads, f"kernel wrapper pad-copied: {our_pads}"
    ref = jnp.einsum("np,n->p", u, w)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


# -- store: streaming reads, dtype, concurrency -------------------------------


def test_store_meta_and_dtype_preserved():
    store = UpdateStore()
    vec = np.asarray(jnp.asarray(
        RNG.normal(size=(64,)).astype(np.float32)
    ).astype(jnp.bfloat16))
    store.write("c0", vec)
    store.write("c1", vec)
    n, p, dtype = store.meta()
    assert (n, p) == (2, 64)
    assert dtype.itemsize == 2, "bf16 must not be upcast to fp32 (2x bytes)"
    assert store.read("c0")[0].dtype == vec.dtype


def test_store_iter_chunks_ragged_and_peak_tracking():
    store = UpdateStore()
    n, p, chunk = 11, 40, 4
    for i in range(n):
        store.write(f"c{i:02d}", RNG.normal(size=(p,)).astype(np.float32),
                    weight=float(i + 1))
    blocks = list(store.iter_chunks(chunk))
    assert [b.shape[0] for b, _ in blocks] == [4, 4, 3]
    stacked = np.concatenate([b for b, _ in blocks])
    ref, wref = store.read_stacked()
    np.testing.assert_array_equal(stacked, ref)
    np.testing.assert_array_equal(
        np.concatenate([w for _, w in blocks]), wref
    )
    # iter_chunks staged at most chunk rows at a time...
    assert min(b.nbytes for b, _ in blocks) <= chunk * p * 4
    # ...while read_stacked's dense block shows up in the peak tracker
    assert store.stats.peak_block_bytes == n * p * 4


def test_store_concurrent_writes_consistent():
    import threading

    store = UpdateStore()
    p = 256

    def writer(k):
        for i in range(25):
            store.write(f"w{k}-{i}", np.full(p, k * 100 + i, np.float32),
                        weight=float(k))

    threads = [threading.Thread(target=writer, args=(k,)) for k in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert store.count() == 100
    assert store.stats.writes == 100
    u, w = store.read("w2-7")
    assert w == 2.0 and u[0] == 207.0


def test_store_disk_bf16_roundtrip(tmp_path):
    """np.save can't round-trip ml_dtypes (bf16 reloads as raw V2); the
    disk backend must spool raw bytes + a dtype sidecar."""
    store = UpdateStore(backend="disk", spool_dir=str(tmp_path))
    vec = np.asarray(jnp.asarray(
        RNG.normal(size=(33,)).astype(np.float32)
    ).astype(jnp.bfloat16))
    store.write("b0", vec, weight=1.5)
    u, w = store.read("b0")
    assert u.dtype == vec.dtype and w == 1.5
    np.testing.assert_array_equal(u, vec)
    n, p, dtype = store.meta()
    assert (n, p) == (1, 33) and dtype == vec.dtype
    # jax must accept the reloaded block (V2 would raise)
    assert jnp.asarray(store.read_stacked()[0]).dtype == jnp.bfloat16
    # overwriting with fp32 clears the stale dtype sidecar
    store.write("b0", np.ones(33, np.float32))
    assert store.read("b0")[0].dtype == np.float32


def test_store_iter_chunks_abandoned_consumer_releases_reader():
    """Dropping the generator mid-stream must not leave the prefetch
    thread blocked holding staged blocks."""
    import threading

    store = UpdateStore()
    for i in range(20):
        store.write(f"c{i:02d}", np.zeros(64, np.float32))
    before = threading.active_count()
    it = store.iter_chunks(2)
    next(it)          # reader now staging/blocked on the full queue
    it.close()        # abandon: GeneratorExit runs the finally
    assert threading.active_count() == before


def test_store_disk_write_outside_lock_roundtrip(tmp_path):
    store = UpdateStore(backend="disk", spool_dir=str(tmp_path))
    store.write("a", np.arange(8, dtype=np.float32), weight=2.5)
    u, w = store.read("a")
    assert w == 2.5
    np.testing.assert_array_equal(u, np.arange(8, dtype=np.float32))
    n, p, dtype = store.meta()
    assert (n, p, dtype) == (1, 8, np.dtype(np.float32))


# -- service: zero-materialization round --------------------------------------


def test_service_store_round_streams_without_dense_read():
    n, p = 32, 1000
    store = UpdateStore()
    updates = RNG.normal(size=(n, p)).astype(np.float32)
    weights = RNG.uniform(1, 5, size=(n,)).astype(np.float32)
    for i in range(n):
        store.write(f"c{i:02d}", updates[i], weight=float(weights[i]))
    row = p * 4
    svc = AggregationService(
        fusion="fedavg", local_strategy="jnp", store=store,
        monitor_timeout=0.5, memory_cap_bytes=8 * row,  # chunk = 4 rows
    )
    with mock.patch.object(
        UpdateStore, "read_stacked",
        side_effect=AssertionError("dense (n, P) host read in stream path"),
    ):
        fused, rep = svc.aggregate(from_store=True, expected_clients=n)
    assert rep.streamed
    assert set(rep.phase_seconds) == {"ingest", "compile", "compute"}
    assert rep.phase_seconds["compile"] > 0.0  # cold first round
    # peak host ingest block is O(chunk * P), not O(n * P)
    assert store.stats.peak_block_bytes <= 4 * row
    manual = np.einsum("np,n->p", updates, weights) / (weights.sum() + 1e-6)
    np.testing.assert_allclose(np.asarray(fused), manual, rtol=1e-4,
                               atol=1e-4)
    # second elastic round, fewer clients, same chunk: warm executable
    store.clear()
    for i in range(n - 5):
        store.write(f"c{i:02d}", updates[i], weight=float(weights[i]))
    before = jitcache.trace_count()
    _, rep2 = svc.aggregate(from_store=True, expected_clients=n - 5)
    assert rep2.streamed
    assert rep2.phase_seconds["compile"] == 0.0
    assert jitcache.trace_count() == before, "warm round re-traced"


def test_service_streams_order_statistics_off_the_store():
    """Order-statistic fusions now stream off the store through the
    top-k carve (PR 7) — bit-matching the dense median."""
    n, p = 10, 64
    store = UpdateStore()
    updates = RNG.normal(size=(n, p)).astype(np.float32)
    for i in range(n):
        store.write(f"c{i}", updates[i])
    svc = AggregationService(fusion="coordmedian", local_strategy="jnp",
                             store=store, monitor_timeout=0.5)
    fused, rep = svc.aggregate(from_store=True, expected_clients=n)
    assert rep.streamed and not rep.notes
    np.testing.assert_allclose(
        np.asarray(fused), np.median(updates, axis=0), rtol=1e-5, atol=1e-6
    )


def test_service_dense_fallback_over_state_budget():
    """A carve whose O(K*P) state exceeds robust_state_budget routes to
    the dense path with an operator note instead of raising."""
    n, p = 10, 64
    store = UpdateStore()
    updates = RNG.normal(size=(n, p)).astype(np.float32)
    for i in range(n):
        store.write(f"c{i}", updates[i])
    svc = AggregationService(fusion="coordmedian", local_strategy="jnp",
                             store=store, monitor_timeout=0.5,
                             robust_state_budget=128)
    fused, rep = svc.aggregate(from_store=True, expected_clients=n)
    assert not rep.streamed
    assert rep.notes and "budget" in rep.notes[0]
    np.testing.assert_allclose(
        np.asarray(fused), np.median(updates, axis=0), rtol=1e-5, atol=1e-6
    )


# -- planner reuse term -------------------------------------------------------


def test_planner_reuse_term_prefers_warm_engine():
    from repro.core import Planner, Workload

    planner = Planner(n_devices=1)
    f = get_fusion("fedavg")
    load = Workload(update_bytes=1 << 20, n_clients=16)
    cold = planner.plan(load, f)
    warm = planner.plan(load, f, warm_engines={"local"})
    assert cold.breakdown["compile"] == planner.compile_overhead
    assert warm.breakdown["compile"] == 0.0
    assert warm.est_seconds < cold.est_seconds
