"""The §IV-C convergence invariant, extended to the async path: every
engine — and every ROUND MODE — computes the same fusion formula.

With staleness discounting disabled, a monitor-overlapped async round
over a fixed client set must be allclose to the synchronous streamed
result, which in turn matches the dense single-chip formula; the
distributed engine's per-shard streaming ingest must match its dense
map-reduce. Async arrival timing is made deterministic with an injected
clock whose ``sleep`` fires scheduled client writes."""
import bisect
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import (
    AggregationService,
    DistributedEngine,
    LocalEngine,
    UpdateStore,
)
from repro.core.fusion import REGISTRY, get_fusion
from repro.utils.compat import make_mesh

RNG = np.random.default_rng(23)

REDUCIBLE = sorted(
    name for name, cls in REGISTRY.items() if cls().reducible
)


class ScriptedClock:
    """Deterministic clock: ``sleep`` advances time and fires any writes
    scheduled to land inside the elapsed window — late arrivals during an
    in-flight stream, reproducibly."""

    def __init__(self):
        self.t = 0.0
        self._events = []   # sorted [(time, fn)]

    def at(self, t, fn):
        bisect.insort(self._events, (t, id(fn), fn))

    def clock(self):
        return self.t

    def sleep(self, seconds):
        self.t += seconds
        while self._events and self._events[0][0] <= self.t:
            _, _, fn = self._events.pop(0)
            fn()


def _mk(n, p):
    u = RNG.normal(size=(n, p)).astype(np.float32)
    w = RNG.uniform(1, 5, size=(n,)).astype(np.float32)
    return u, w


def _service(store, clk, fusion="fedavg", **kw):
    kw.setdefault("threshold_frac", 1.0)
    kw.setdefault("monitor_timeout", 60.0)
    return AggregationService(
        fusion=fusion, local_strategy="jnp", store=store,
        clock=clk.clock, sleep=clk.sleep, **kw,
    )


# -- async round == sync streamed == dense ------------------------------------


@pytest.mark.parametrize("name", REDUCIBLE)
def test_async_round_matches_sync_streamed(name):
    """Fixed client set, arrivals spread over the monitor window, NO
    staleness discount: the overlapped round is allclose to the
    serialized streamed round and the dense formula."""
    n, p = 11, 301
    u, w = _mk(n, p)

    # dense reference and serialized streamed result
    dense = np.asarray(
        LocalEngine(strategy="jnp").fuse(get_fusion(name), u, w)
    )
    store_sync = UpdateStore()
    for i in range(n):
        store_sync.write(f"c{i:02d}", u[i], weight=float(w[i]))
    sync_svc = AggregationService(
        fusion=name, local_strategy="jnp", store=store_sync,
        monitor_timeout=1.0, memory_cap_bytes=3 * p * 4 * 2,
    )
    sync_fused, sync_rep = sync_svc.aggregate(
        from_store=True, expected_clients=n,
    )
    assert sync_rep.streamed and not sync_rep.async_round

    # overlapped round: client i lands at t = 0.05 * (i + 1)
    clk = ScriptedClock()
    store = UpdateStore()
    for i in range(n):
        clk.at(0.05 * (i + 1),
               lambda i=i: store.write(f"c{i:02d}", u[i], weight=float(w[i])))
    svc = _service(store, clk, fusion=name,
                   memory_cap_bytes=3 * p * 4 * 2)
    fused, rep = svc.aggregate(
        from_store=True, expected_clients=n, async_round=True,
    )
    assert rep.async_round and rep.streamed
    assert rep.monitor.ready and rep.n_clients == n
    assert rep.overlap_seconds > 0
    np.testing.assert_allclose(
        np.asarray(fused), np.asarray(sync_fused), rtol=1e-5, atol=1e-6
    )
    np.testing.assert_allclose(np.asarray(fused), dense, rtol=1e-4,
                               atol=1e-5)
    assert store.count() == 0   # async rounds consume what they fold


# -- distributed per-shard streaming == dense ---------------------------------


@pytest.mark.parametrize("name", REDUCIBLE)
def test_distributed_stream_matches_dense(name):
    n, p, chunk = 13, 257, 4
    u, w = _mk(n, p)
    dense = np.asarray(
        LocalEngine(strategy="jnp").fuse(get_fusion(name), u, w)
    )
    mesh = make_mesh((1, 1), ("data", "model"))
    eng = DistributedEngine(mesh=mesh)

    def blocks():
        for lo in range(0, n, chunk):
            yield u[lo:lo + chunk], w[lo:lo + chunk]

    streamed, rep = eng.fuse_stream(get_fusion(name), blocks())
    np.testing.assert_allclose(np.asarray(streamed), dense, rtol=1e-4,
                               atol=1e-5)
    assert rep.n_rows == n and rep.n_blocks == -(-n // chunk)
    assert rep.compile_seconds > 0.0   # cold
    streamed2, rep2 = eng.fuse_stream(get_fusion(name), blocks())
    assert rep2.compile_seconds == 0.0  # warm: cached shard_map step
    np.testing.assert_allclose(np.asarray(streamed2), dense, rtol=1e-4,
                               atol=1e-5)


def test_distributed_stream_accumulator_carry():
    """Carried partial sums split across two streams equal one stream."""
    n, p = 12, 130
    u, w = _mk(n, p)
    mesh = make_mesh((1, 1), ("data", "model"))
    eng = DistributedEngine(mesh=mesh)
    f = get_fusion("fedavg")
    full, _ = eng.fuse_stream(f, [(u, w)])
    _, rep1 = eng.fuse_stream(f, [(u[:5], w[:5])])
    part2, _ = eng.fuse_stream(
        f, [(u[5:], w[5:])], init=(rep1.acc_wsum, rep1.acc_tot)
    )
    np.testing.assert_allclose(np.asarray(part2), np.asarray(full),
                               rtol=1e-5, atol=1e-6)


def test_distributed_stream_multidevice_subprocess():
    """8-device mesh: per-shard streamed ingest == dense map-reduce ==
    local. Forced host device counts only in the subprocess."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=8 "
            + os.environ.get("XLA_FLAGS", "")
        )
        import numpy as np
        from repro.core import DistributedEngine, LocalEngine
        from repro.core.fusion import get_fusion
        from repro.utils.compat import make_mesh

        rng = np.random.default_rng(7)
        n, p, chunk = 21, 266, 6
        u = rng.normal(size=(n, p)).astype(np.float32)
        w = rng.uniform(1, 5, size=(n,)).astype(np.float32)
        mesh = make_mesh((4, 2), ("data", "model"))
        eng = DistributedEngine(mesh=mesh)
        f = get_fusion("clippedavg")   # exercises the psum'd row norms
        dense = np.asarray(eng.fuse(f, u, w))
        local = np.asarray(LocalEngine(strategy="jnp").fuse(f, u, w))

        def blocks():
            for lo in range(0, n, chunk):
                yield u[lo:lo + chunk], w[lo:lo + chunk]

        streamed, rep = eng.fuse_stream(f, blocks())
        np.testing.assert_allclose(np.asarray(streamed), dense,
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(streamed), local,
                                   rtol=1e-4, atol=1e-5)
        assert rep.n_rows == n
        print("OK")
    """)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout
