"""End-to-end behaviour: federated training converges, the adaptive
service routes correctly across rounds, engines interoperate with the FL
loop, and the CLI drivers run."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import AggregationService, UpdateStore
from repro.data import FederatedLoader, SyntheticLM
from repro.fl import Client, FederatedServer
from repro.models import build_model
from repro.optim import sgd


def _tiny_setup(fusion="fedavg", n_clients=4, local_steps=2, lr=0.5,
                send_delta=False, vocab=128):
    cfg = get_config("qwen2-0.5b").reduced()
    # shrink further for speed
    import dataclasses
    cfg = dataclasses.replace(cfg, vocab=vocab, n_layers=2, d_model=64,
                              n_heads=2, n_kv_heads=1, d_ff=128, head_dim=32)
    model = build_model(cfg)
    gen = SyntheticLM(vocab=cfg.vocab, seed=0, temperature=0.5)
    loader = FederatedLoader(gen=gen, n_clients=n_clients, batch=8,
                             seq_len=32)
    clients = [
        Client(client_id=i, model=model, optimizer=sgd(lr),
               local_steps=local_steps, send_delta=send_delta)
        for i in range(n_clients)
    ]
    service = AggregationService(fusion=fusion, local_strategy="jnp")
    server = FederatedServer(model=model, clients=clients, loader=loader,
                             service=service)
    return server


def test_federated_training_converges():
    """Loss must drop substantially over rounds — the paper's §IV-C
    invariant is that the SERVICE never changes convergence."""
    server = _tiny_setup()
    results = server.run(12)
    first = np.mean([r.mean_client_loss for r in results[:2]])
    last = np.mean([r.mean_client_loss for r in results[-2:]])
    assert last < first - 0.3, (first, last)


def test_gradavg_delta_path_converges():
    server = _tiny_setup(fusion="gradavg", send_delta=True, lr=0.5)
    results = server.run(12)
    first = np.mean([r.mean_client_loss for r in results[:2]])
    last = np.mean([r.mean_client_loss for r in results[-2:]])
    assert last < first - 0.2, (first, last)


def test_robust_fusion_survives_byzantine_client():
    """With coordinate-median fusion, one garbage client must not destroy
    the model (with fedavg it would)."""
    server = _tiny_setup(fusion="coordmedian", n_clients=5)

    bad = server.clients[0]
    orig_round = bad.train_round

    def poisoned(global_params, batch_fn, round_idx):
        upd, loss = orig_round(global_params, batch_fn, round_idx)
        upd = jax.tree_util.tree_map(
            lambda u: u + 100.0 * jnp.sign(u), upd
        )
        return upd, loss

    bad.train_round = poisoned
    results = server.run(8)
    assert np.isfinite(results[-1].mean_client_loss)
    assert results[-1].mean_client_loss < results[0].mean_client_loss + 1.0


def test_round_reports_expose_plan():
    server = _tiny_setup()
    res = server.run_round(0)
    assert res.report.plan.engine == "local"
    assert res.report.plan.feasible
    assert res.n_selected == 4


def test_train_cli_runs():
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "qwen2-0.5b",
         "--rounds", "2", "--clients", "2", "--local-steps", "1",
         "--batch", "2", "--seq-len", "16"],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"},
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "[round   1]" in r.stdout


def test_serve_cli_runs():
    """The ingest-service entrypoint end to end: real HTTP uploaders
    replaying a trace, fair-scheduled rounds, full inclusion."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--tenants", "2",
         "--clients", "6", "--dim", "2000", "--rounds", "1",
         "--spread", "0.1"],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"},
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "included=6/6" in r.stdout
    assert "uploads=12" in r.stdout


def test_aggregate_cli_runs():
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.aggregate", "--model", "CNN4.6",
         "--clients", "6"],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"},
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "engine=" in r.stdout
