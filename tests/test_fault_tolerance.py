"""Fault tolerance + strong §IV-C: aggregator restart recovery and
engine-independent training trajectories."""
import numpy as np
import pytest

from repro.core import AggregationService, LocalEngine, UpdateStore
from repro.core.fusion import FedAvg

RNG = np.random.default_rng(31)


def test_store_survives_aggregator_restart(tmp_path):
    """The paper leans on HDFS durability: updates written before an
    aggregator crash must be aggregatable by its replacement."""
    spool = str(tmp_path / "spool")
    store1 = UpdateStore(backend="disk", spool_dir=spool)
    ups = RNG.normal(size=(5, 64)).astype(np.float32)
    for i in range(5):
        store1.write(f"c{i}", ups[i], weight=float(i + 1))
    del store1  # "crash"

    store2 = UpdateStore(backend="disk", spool_dir=spool)  # new incarnation
    assert store2.count() == 5
    stacked, w = store2.read_stacked()
    np.testing.assert_array_equal(w, np.arange(1, 6, dtype=np.float32))
    svc = AggregationService(fusion="fedavg", store=store2,
                             local_strategy="jnp", monitor_timeout=0.5)
    fused, rep = svc.aggregate(from_store=True, expected_clients=5)
    expect = (ups * w[:, None]).sum(0) / (w.sum() + 1e-6)
    np.testing.assert_allclose(np.asarray(fused), expect, rtol=1e-5,
                               atol=1e-6)


def test_partial_spool_recovery(tmp_path):
    """A crash mid-round (missing weight sidecar) degrades gracefully to
    weight=1 instead of losing the update."""
    import os

    spool = str(tmp_path / "spool")
    store1 = UpdateStore(backend="disk", spool_dir=spool)
    store1.write("a", np.ones(8, np.float32), weight=7.0)
    store1.write("b", np.ones(8, np.float32), weight=3.0)
    os.remove(os.path.join(spool, "a.npy.w"))  # lost sidecar
    store2 = UpdateStore(backend="disk", spool_dir=spool)
    assert store2.count() == 2
    u, w = store2.read("a")
    assert w == 1.0  # graceful default
    _, wb = store2.read("b")
    assert wb == 3.0


def test_training_trajectory_engine_independent():
    """§IV-C, strong form: an entire FL run produces the same global
    params whichever engine fuses each round."""
    import dataclasses

    import jax

    from repro.configs import get_config
    from repro.data import FederatedLoader, SyntheticLM
    from repro.fl import Client, FederatedServer
    from repro.models import build_model
    from repro.optim import sgd

    def run(strategy, cap):
        cfg = dataclasses.replace(
            get_config("qwen2-0.5b").reduced(), vocab=64, n_layers=1,
            d_model=32, n_heads=2, n_kv_heads=1, d_ff=64, head_dim=16,
        )
        model = build_model(cfg)
        loader = FederatedLoader(
            gen=SyntheticLM(vocab=64, seed=0), n_clients=3, batch=4,
            seq_len=16,
        )
        clients = [
            Client(client_id=i, model=model, optimizer=sgd(0.3),
                   local_steps=1)
            for i in range(3)
        ]
        svc = AggregationService(fusion="fedavg", local_strategy=strategy,
                                 memory_cap_bytes=cap)
        server = FederatedServer(model=model, clients=clients,
                                 loader=loader, service=svc)
        server.run(3)
        return server.params

    p_full = run("jnp", None)
    # memory-capped => streamed accumulation engine path
    p_stream = run("jnp", 2 * 400_000)
    for a, b in zip(jax.tree_util.tree_leaves(p_full),
                    jax.tree_util.tree_leaves(p_stream)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-5)
