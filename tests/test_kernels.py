"""Per-kernel shape/dtype sweeps against the pure-jnp ref.py oracles
(interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.fused_fusion.kernel import (
    weighted_sum_dequant_pallas,
    weighted_sum_pallas,
)
from repro.kernels.fused_fusion.ops import (
    fedavg_fused,
    fedavg_fused_dequant,
    iteravg_fused,
)
from repro.kernels.fused_fusion.ref import (
    fedavg_ref,
    weighted_sum_dequant_ref,
    weighted_sum_ref,
)
from repro.kernels.robust_fusion.kernel import (
    coordmedian_pallas,
    trimmedmean_pallas,
)
from repro.kernels.robust_fusion.ref import coordmedian_ref, trimmedmean_ref

RNG = np.random.default_rng(7)


# -- fused_fusion -------------------------------------------------------------


@pytest.mark.parametrize("n,p", [(1, 16), (3, 127), (8, 1024), (37, 5003),
                                 (65, 2048), (256, 301)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16, np.float16])
def test_weighted_sum_shapes_dtypes(n, p, dtype):
    u = jnp.asarray(RNG.normal(size=(n, p)).astype(np.float32)).astype(dtype)
    w = jnp.asarray(RNG.uniform(1, 4, size=(n,)).astype(np.float32))
    out = weighted_sum_pallas(u, w)
    ref = weighted_sum_ref(u, w)
    tol = 2e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(out, ref, rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("pt,ct", [(128, 8), (512, 32), (2048, 256)])
def test_weighted_sum_tile_sweep(pt, ct):
    u = jnp.asarray(RNG.normal(size=(40, 700)).astype(np.float32))
    w = jnp.asarray(RNG.uniform(1, 4, size=(40,)).astype(np.float32))
    out = weighted_sum_pallas(u, w, param_tile=pt, client_tile=ct)
    np.testing.assert_allclose(out, weighted_sum_ref(u, w), rtol=2e-5,
                               atol=1e-4)


def test_fedavg_iteravg_ops():
    u = jnp.asarray(RNG.normal(size=(9, 333)).astype(np.float32))
    w = jnp.asarray(RNG.uniform(1, 9, size=(9,)).astype(np.float32))
    np.testing.assert_allclose(fedavg_fused(u, w), fedavg_ref(u, w),
                               rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(
        iteravg_fused(u), np.asarray(u).mean(0), rtol=2e-5, atol=1e-5
    )


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 40), p=st.integers(1, 600), seed=st.integers(0, 999))
def test_weighted_sum_property(n, p, seed):
    r = np.random.default_rng(seed)
    u = jnp.asarray(r.normal(size=(n, p)).astype(np.float32))
    w = jnp.asarray(r.uniform(0, 3, size=(n,)).astype(np.float32))
    np.testing.assert_allclose(
        weighted_sum_pallas(u, w), weighted_sum_ref(u, w),
        rtol=1e-4, atol=1e-3,
    )


# -- fused_fusion: in-kernel dequant fold -------------------------------------


def _quantized(n, p, block, rng):
    """Random (codes, scales, weights) with Pq padded to the block."""
    n_blocks = -(-p // block)
    codes = rng.integers(-127, 128, size=(n, n_blocks * block),
                         dtype=np.int8)
    codes[:, p:] = 0
    scales = rng.uniform(1e-4, 1e-2, size=(n, n_blocks)).astype(np.float32)
    w = rng.uniform(1, 4, size=(n,)).astype(np.float32)
    return jnp.asarray(codes), jnp.asarray(scales), jnp.asarray(w)


@pytest.mark.parametrize("n,p,block", [
    (1, 128, 128),        # single client, single tile
    (5, 5003, 2048),      # ragged param dim, default block
    (37, 4096, 2048),     # multi-tile clients
    (65, 300, 128),       # ragged client tile + small block
    (256, 1024, 256),     # many clients
])
def test_weighted_sum_dequant_parity(n, p, block):
    q, s, w = _quantized(n, p, block, np.random.default_rng(n * 1000 + p))
    out = weighted_sum_dequant_pallas(q, s, w, block=block)
    ref = weighted_sum_dequant_ref(q, s, w, block=block)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=1e-4)


def test_weighted_sum_dequant_matches_dense_kernel():
    """Folding the scales in-kernel must equal dequantizing first and
    running the dense weighted-sum kernel."""
    rng = np.random.default_rng(3)
    q, s, w = _quantized(19, 6000, 2048, rng)
    blk = 2048
    nb = q.shape[1] // blk
    dense = (np.asarray(q, np.float32).reshape(19, nb, blk)
             * np.asarray(s)[:, :, None]).reshape(19, -1)
    np.testing.assert_allclose(
        weighted_sum_dequant_pallas(q, s, w),
        weighted_sum_pallas(jnp.asarray(dense), w),
        rtol=2e-5, atol=1e-4,
    )


def test_fedavg_fused_dequant_op():
    rng = np.random.default_rng(5)
    q, s, w = _quantized(9, 3000, 1024, rng)
    out = fedavg_fused_dequant(q, s, w, block=1024)
    ref = weighted_sum_dequant_ref(q, s, w, block=1024) / jnp.sum(w)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 40), nb=st.integers(1, 6), seed=st.integers(0, 999))
def test_weighted_sum_dequant_property(n, nb, seed):
    block = 128
    q, s, w = _quantized(n, nb * block, block, np.random.default_rng(seed))
    np.testing.assert_allclose(
        weighted_sum_dequant_pallas(q, s, w, block=block),
        weighted_sum_dequant_ref(q, s, w, block=block),
        rtol=1e-4, atol=1e-3,
    )


# -- robust_fusion ------------------------------------------------------------


@pytest.mark.parametrize("n,p", [(3, 64), (8, 1025), (17, 4096), (33, 100)])
def test_coordmedian_sweep(n, p):
    u = jnp.asarray(RNG.normal(size=(n, p)).astype(np.float32))
    np.testing.assert_allclose(
        coordmedian_pallas(u), coordmedian_ref(u), rtol=1e-6, atol=1e-6
    )


@pytest.mark.parametrize("n,trim", [(9, 0), (9, 2), (20, 5)])
def test_trimmedmean_sweep(n, trim):
    u = jnp.asarray(RNG.normal(size=(n, 513)).astype(np.float32))
    np.testing.assert_allclose(
        trimmedmean_pallas(u, trim), trimmedmean_ref(u, trim),
        rtol=1e-5, atol=1e-5,
    )


# -- flash_attention ----------------------------------------------------------


@pytest.mark.parametrize("T,nq,nkv,hd", [
    (128, 4, 4, 64),    # MHA
    (128, 8, 2, 64),    # GQA 4:1
    (256, 4, 1, 128),   # MQA, bigger head
])
@pytest.mark.parametrize("window", [0, 64])
def test_flash_attention_sweep(T, nq, nkv, hd, window):
    B = 2
    q = jnp.asarray(RNG.normal(size=(B, T, nq, hd)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(B, T, nkv, hd)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(B, T, nkv, hd)).astype(np.float32))
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=3e-5)


def test_flash_attention_bf16():
    B, T, nq, nkv, hd = 2, 128, 4, 2, 64
    mk = lambda s: jnp.asarray(
        RNG.normal(size=s).astype(np.float32)
    ).astype(jnp.bfloat16)
    q, k, v = mk((B, T, nq, hd)), mk((B, T, nkv, hd)), mk((B, T, nkv, hd))
    out = flash_attention(q, k, v, block_q=64, block_k=64)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=5e-2, atol=5e-2,
    )


def test_flash_matches_model_blockwise():
    """The Pallas kernel and the model's pure-jnp blockwise path agree."""
    from repro.models.layers.attention import blockwise_attention

    B, T, nq, nkv, hd = 2, 256, 6, 2, 64
    q = jnp.asarray(RNG.normal(size=(B, T, nq, hd)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(B, T, nkv, hd)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(B, T, nkv, hd)).astype(np.float32))
    a = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    b = blockwise_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=3e-5)
