"""Monitor-overlapped async rounds: gate edge cases (deterministic
injected clock), arrival-driven store iteration, queue/staleness
semantics, and the store/service correctness fixes that ride along:

  * timed-out round on an empty store returns a structured empty report
    (no LookupError out of ``store.meta()``);
  * ``UpdateStore.clear()`` resets stats and deletes spool blobs outside
    the lock; ``remove()`` consumes; memory-backend ``read()`` hands out
    immutable views;
  * distributed rounds surface a ``compile`` phase (cold vs warm).
"""
import bisect
import os

import numpy as np
import pytest

from repro.core import (
    AggregationService,
    DistributedEngine,
    LocalEngine,
    Monitor,
    Planner,
    UpdateStore,
    Workload,
    get_fusion,
)
from repro.utils.compat import make_mesh

RNG = np.random.default_rng(31)


class ScriptedClock:
    def __init__(self):
        self.t = 0.0
        self._events = []

    def at(self, t, fn):
        bisect.insort(self._events, (t, id(fn), fn))

    def clock(self):
        return self.t

    def sleep(self, seconds):
        self.t += seconds
        while self._events and self._events[0][0] <= self.t:
            _, _, fn = self._events.pop(0)
            fn()


def _mk(n, p=64):
    u = RNG.normal(size=(n, p)).astype(np.float32)
    w = RNG.uniform(1, 5, size=(n,)).astype(np.float32)
    return u, w


def _fedavg(u, w):
    return np.einsum("np,n->p", u, w) / (w.sum() + 1e-6)


def _service(store, clk, **kw):
    kw.setdefault("threshold_frac", 1.0)
    return AggregationService(
        fusion="fedavg", local_strategy="jnp", store=store,
        clock=clk.clock, sleep=clk.sleep, **kw,
    )


# -- monitor gate edge cases ---------------------------------------------------


def test_async_timeout_zero_arrivals_empty_report():
    clk = ScriptedClock()
    store = UpdateStore()
    svc = _service(store, clk, monitor_timeout=1.0)
    fused, rep = svc.aggregate(from_store=True, expected_clients=5,
                               async_round=True)
    assert fused is None and rep.empty and rep.async_round
    assert not rep.monitor.ready and rep.monitor.count == 0
    assert rep.monitor.waited >= 1.0
    assert rep.n_clients == 0 and rep.fuse_seconds == 0.0


def test_sync_timeout_empty_store_no_crash():
    """The satellite bug verbatim: serialized store round, empty store,
    monitor times out -> structured report, not LookupError."""
    clk = ScriptedClock()
    svc = _service(UpdateStore(), clk, monitor_timeout=0.5)
    fused, rep = svc.aggregate(from_store=True)
    assert fused is None and rep.empty and not rep.async_round
    assert rep.monitor is not None and not rep.monitor.ready


def test_async_timeout_partial_arrivals():
    """3 of 8 land before the deadline: the round folds exactly those 3
    and reports ready=False."""
    n, p = 8, 96
    u, w = _mk(n, p)
    clk = ScriptedClock()
    store = UpdateStore()
    for i in range(3):
        clk.at(0.2 * (i + 1),
               lambda i=i: store.write(f"c{i}", u[i], weight=float(w[i])))
    # clients 3..7 never arrive
    svc = _service(store, clk, monitor_timeout=2.0)
    fused, rep = svc.aggregate(from_store=True, expected_clients=n,
                               async_round=True)
    assert not rep.monitor.ready and rep.monitor.count == 3
    assert rep.n_clients == 3
    np.testing.assert_allclose(
        np.asarray(fused), _fedavg(u[:3], w[:3]), rtol=1e-4, atol=1e-5
    )


def test_threshold_reached_exactly_at_timeout():
    """The last required update lands at t == timeout: threshold wins the
    tie — the round is ready, not timed out (both for Monitor.wait and
    the async gate)."""
    n, p = 4, 32
    u, w = _mk(n, p)
    timeout = 1.0

    clk = ScriptedClock()
    store = UpdateStore()
    mon = Monitor(store, threshold=n, timeout=timeout, poll_interval=0.1,
                  clock=clk.clock, sleep=clk.sleep)
    for i in range(n - 1):
        clk.at(0.2, lambda i=i: store.write(f"c{i}", u[i],
                                            weight=float(w[i])))
    clk.at(timeout, lambda: store.write(f"c{n-1}", u[n - 1],
                                        weight=float(w[n - 1])))
    res = mon.wait()
    assert res.ready and res.count == n and res.waited >= timeout

    clk2 = ScriptedClock()
    store2 = UpdateStore()
    for i in range(n - 1):
        clk2.at(0.2, lambda i=i: store2.write(f"c{i}", u[i],
                                              weight=float(w[i])))
    clk2.at(timeout, lambda: store2.write(f"c{n-1}", u[n - 1],
                                          weight=float(w[n - 1])))
    svc = _service(store2, clk2, monitor_timeout=timeout)
    fused, rep = svc.aggregate(from_store=True, expected_clients=n,
                               async_round=True)
    assert rep.monitor.ready and rep.n_clients == n
    np.testing.assert_allclose(np.asarray(fused), _fedavg(u, w),
                               rtol=1e-4, atol=1e-5)


def test_late_writes_land_during_inflight_stream():
    """Writes scheduled AFTER the stream opens are picked up by the live
    iterator (no up-front snapshot) and fold into the same round."""
    n, p, chunk = 9, 40, 2
    u, w = _mk(n, p)
    clk = ScriptedClock()
    store = UpdateStore()
    # two present at the start, the rest trickle in while in-flight
    for i in range(2):
        store.write(f"c{i:02d}", u[i], weight=float(w[i]))
    for i in range(2, n):
        clk.at(0.1 * i, lambda i=i: store.write(f"c{i:02d}", u[i],
                                                weight=float(w[i])))
    seen_counts = []

    def gate(count, waited):
        seen_counts.append(count)
        return count >= n or waited >= 5.0

    got = list(store.iter_arrivals(
        chunk, gate, poll_interval=0.05, clock=clk.clock, sleep=clk.sleep,
    ))
    assert sum(b.shape[0] for b, _, _ in got) == n
    # only the FINAL block may be ragged (fixed-shape step executables)
    assert all(b.shape[0] == chunk for b, _, _ in got[:-1])
    # the stream saw the count GROW while in flight: arrival-driven
    assert seen_counts[0] < n and max(seen_counts) == n
    stacked = np.concatenate([b for b, _, _ in got])
    ws = np.concatenate([wb for _, wb, _ in got])
    np.testing.assert_allclose(
        _fedavg(stacked, ws), _fedavg(u, w), rtol=1e-4, atol=1e-5
    )


# -- queue + staleness semantics ----------------------------------------------


def test_async_consumes_folded_and_ages_stragglers():
    n, p = 6, 48
    u, w = _mk(n, p)
    clk = ScriptedClock()
    store = UpdateStore()
    for i in range(4):
        store.write(f"c{i}", u[i], weight=float(w[i]))
    svc = _service(store, clk, monitor_timeout=0.5,
                   staleness_discount=0.5, threshold_frac=1.0)
    fused, rep = svc.aggregate(from_store=True, expected_clients=4,
                               async_round=True)
    assert store.count() == 0        # folded rows consumed
    # a straggler arrives between rounds -> folds next round at gamma^1
    store.write("late", u[4], weight=float(w[4]))
    fused2, rep2 = svc.aggregate(from_store=True, expected_clients=1,
                                 async_round=True)
    g = 0.5
    ws1 = np.einsum("np,n->p", u[:4], w[:4])
    tot1 = w[:4].sum()
    # carry decays by gamma; the late update is fresh this round (age 0)
    ws2 = g * ws1 + w[4] * u[4]
    tot2 = g * tot1 + w[4]
    np.testing.assert_allclose(
        np.asarray(fused2), ws2 / (tot2 + 1e-6), rtol=1e-4, atol=1e-5
    )


def test_staleness_discount_validation():
    with pytest.raises(ValueError):
        AggregationService(fusion="fedavg", staleness_discount=0.0)
    with pytest.raises(ValueError):
        AggregationService(fusion="fedavg", staleness_discount=1.5)


def test_async_falls_back_to_sync_for_non_streamable():
    """Fusions with no reducer decomposition (Krum) cannot fold
    incrementally: async_round is ignored and the dense path runs."""
    n, p = 6, 32
    u, _ = _mk(n, p)
    store = UpdateStore()
    for i in range(n):
        store.write(f"c{i}", u[i])
    svc = AggregationService(fusion="krum", local_strategy="jnp",
                             store=store, monitor_timeout=0.5)
    fused, rep = svc.aggregate(from_store=True, expected_clients=n,
                               async_round=True)
    assert not rep.async_round and not rep.streamed
    ref = np.asarray(get_fusion("krum").fuse(u, np.ones(n, np.float32)))
    np.testing.assert_allclose(np.asarray(fused), ref, rtol=1e-5, atol=1e-6)


def test_async_falls_back_to_sync_over_carve_budget():
    """An order-statistic round whose carve state exceeds the budget
    runs synchronously (dense) even with async_round=True."""
    n, p = 6, 32
    u, _ = _mk(n, p)
    store = UpdateStore()
    for i in range(n):
        store.write(f"c{i}", u[i])
    svc = AggregationService(fusion="coordmedian", local_strategy="jnp",
                             store=store, monitor_timeout=0.5,
                             robust_state_budget=64)
    fused, rep = svc.aggregate(from_store=True, expected_clients=n,
                               async_round=True)
    assert not rep.async_round and not rep.streamed
    assert rep.notes and "budget" in rep.notes[0]
    np.testing.assert_allclose(
        np.asarray(fused), np.median(u, axis=0), rtol=1e-5, atol=1e-6
    )


def test_async_without_expected_clients_is_timeout_gated():
    """Async rounds start BEFORE arrivals by design; with no
    expected_clients the gate must run the full timeout window and fold
    everything that lands — not close on the first client (the
    threshold=1 default the serialized path tolerated)."""
    n, p = 5, 32
    u, w = _mk(n, p)
    clk = ScriptedClock()
    store = UpdateStore()   # empty at round start
    for i in range(n):
        clk.at(0.3 * (i + 1),
               lambda i=i: store.write(f"c{i}", u[i], weight=float(w[i])))
    svc = _service(store, clk, monitor_timeout=2.0)
    fused, rep = svc.aggregate(from_store=True, async_round=True)
    assert rep.n_clients == n, "gate closed before the stragglers landed"
    assert not rep.monitor.ready    # timeout-gated rounds never 'fill'
    np.testing.assert_allclose(np.asarray(fused), _fedavg(u, w),
                               rtol=1e-4, atol=1e-5)


def test_async_rewrite_during_round_not_lost():
    """A client that re-writes its update AFTER the round folded the old
    version must not lose the new one to the post-round consume: the
    version-checked remove keeps it for the next round."""
    n, p = 4, 32
    u, w = _mk(n + 1, p)
    clk = ScriptedClock()
    store = UpdateStore()
    for i in range(n):
        store.write(f"c{i}", u[i], weight=float(w[i]))
    # c0 re-writes while the round is in flight, after its fold but
    # before the gate closes (threshold n is met only at t=0.5)
    clk.at(0.3, lambda: store.write("c0", u[n], weight=9.0))
    clk.at(0.5, lambda: store.write("late-filler", u[n], weight=1.0))

    svc = _service(store, clk, monitor_timeout=2.0,
                   stream_chunk_bytes=2 * p * 4)  # chunk of 2: early fold
    fused, rep = svc.aggregate(from_store=True, expected_clients=n + 1,
                               async_round=True)
    # the re-written c0 survived the consume for the NEXT round
    assert store.client_ids() == ["c0"]
    nv, nw = store.read("c0")
    assert nw == 9.0
    np.testing.assert_array_equal(np.asarray(nv), u[n])


def test_fuse_stream_rejects_raw_iter_arrivals():
    """Feeding iter_arrivals (ids in the third slot) straight into an
    engine must fail loudly, not corrupt weights."""
    store = UpdateStore()
    for i in range(4):
        store.write(f"c{i}", np.ones(8, np.float32))
    eng = LocalEngine(strategy="jnp")
    with pytest.raises(TypeError, match="iter_arrivals"):
        eng.fuse_stream(
            get_fusion("fedavg"),
            store.iter_arrivals(2, lambda c, t: c >= 4),
        )


def test_async_variable_close_counts_share_one_executable():
    """Rounds closing at different arrival counts (single ragged block)
    must reuse the executable keyed on the CONFIGURED chunk, not the
    observed block size — and that is the key _warm_engines probes."""
    from repro.utils import jitcache

    p = 40
    u, w = _mk(8, p)
    f = get_fusion("fedavg")
    eng = LocalEngine(strategy="jnp")
    chunk = 8
    out1, rep1 = eng.fuse_stream(f, [(u[:5], w[:5])], chunk_rows=chunk)
    assert rep1.chunk_rows == chunk
    assert eng.is_warm_stream(f, chunk, p, np.float32)
    before = jitcache.trace_count()
    out2, rep2 = eng.fuse_stream(f, [(u[:7], w[:7])], chunk_rows=chunk)
    assert jitcache.trace_count() == before, "variable close count re-traced"
    assert rep2.compile_seconds == 0.0
    np.testing.assert_allclose(np.asarray(out2), _fedavg(u[:7], w[:7]),
                               rtol=1e-4, atol=1e-5)


def test_async_phase_ingest_excludes_idle_wait():
    """phase_seconds['ingest'] on an async round is block-staging I/O,
    not the straggler wait (which is the overlap phase)."""
    n, p = 6, 64
    u, w = _mk(n, p)
    clk = ScriptedClock()
    store = UpdateStore()
    for i in range(n):
        clk.at(0.5 * (i + 1),
               lambda i=i: store.write(f"c{i}", u[i], weight=float(w[i])))
    svc = _service(store, clk, monitor_timeout=10.0)
    fused, rep = svc.aggregate(from_store=True, expected_clients=n,
                               async_round=True)
    # 3 s of scripted wait; real I/O for 6 tiny rows is far under 1 s
    assert rep.overlap_seconds >= 3.0
    assert rep.phase_seconds["overlap"] >= 3.0
    assert rep.phase_seconds["ingest"] < 1.0


# -- planner overlap costing ---------------------------------------------------


def test_planner_prefers_async_when_wait_dominates():
    planner = Planner(n_devices=1)
    f = get_fusion("fedavg")
    load = Workload(update_bytes=4 << 20, n_clients=64)
    assert planner.prefer_async(load, f, expected_wait=5.0)
    assert not planner.prefer_async(load, f, expected_wait=0.0)
    assert not planner.prefer_async(load, get_fusion("krum"), 5.0)
    plan = planner.plan(load, f)
    ser, ovl = planner.overlap_estimate(plan, expected_wait=5.0)
    assert ser == pytest.approx(5.0 + plan.est_seconds)
    assert ovl == pytest.approx(
        max(5.0, plan.est_seconds) + planner.overlap_drain_seconds
    )


# -- store fixes ---------------------------------------------------------------


def test_store_read_returns_immutable_view():
    store = UpdateStore()
    store.write("a", np.arange(8, dtype=np.float32))
    u, _ = store.read("a")
    assert not u.flags.writeable
    with pytest.raises(ValueError):
        u[0] = 99.0
    # the spool itself is untouched by the attempt
    fresh, _ = store.read("a")
    assert fresh[0] == 0.0


def test_store_clear_resets_stats_and_unlinks(tmp_path):
    store = UpdateStore(backend="disk", spool_dir=str(tmp_path))
    store.write("a", np.ones(16, np.float32), weight=2.0)
    store.write("b", np.ones(16, np.float32))
    store.read_stacked()
    assert store.stats.writes == 2 and store.stats.reads == 2
    assert store.stats.peak_block_bytes > 0
    store.clear()
    assert store.count() == 0
    assert store.stats.writes == 0 and store.stats.bytes_written == 0
    assert store.stats.reads == 0 and store.stats.peak_block_bytes == 0
    leftovers = [f for f in os.listdir(tmp_path)]
    assert leftovers == []
    # a fresh incarnation recovers nothing
    assert UpdateStore(backend="disk", spool_dir=str(tmp_path)).count() == 0


def test_store_remove_consumes_subset(tmp_path):
    for backend, kw in (("memory", {}),
                        ("disk", {"spool_dir": str(tmp_path)})):
        store = UpdateStore(backend=backend, **kw)
        for i in range(5):
            store.write(f"c{i}", np.full(4, i, np.float32))
        store.remove(["c1", "c3", "missing-id"])
        assert store.client_ids() == ["c0", "c2", "c4"]
        u, _ = store.read("c2")
        assert u[0] == 2.0


# -- distributed compile phase -------------------------------------------------


def test_distributed_cold_vs_warm_compile_phase():
    mesh = make_mesh((1, 1), ("data", "model"))
    eng = DistributedEngine(mesh=mesh)
    f = get_fusion("iteravg")
    n, p = 10, 129
    u, w = _mk(n, p)
    ref = np.asarray(LocalEngine(strategy="jnp").fuse(f, u, w))
    out1 = np.asarray(eng.fuse(f, u, w))
    cold = eng.last_compile_seconds
    out2 = np.asarray(eng.fuse(f, u, w))
    warm = eng.last_compile_seconds
    assert cold > 0.0 and warm == 0.0
    np.testing.assert_allclose(out1, ref, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(out2, ref, rtol=1e-4, atol=1e-5)


def test_distributed_is_warm_stream():
    mesh = make_mesh((1, 1), ("data", "model"))
    eng = DistributedEngine(mesh=mesh)
    f = get_fusion("fedavg")
    u, w = _mk(8, 64)
    assert not eng.is_warm_stream(f, 4, 64, np.float32)
    eng.fuse_stream(f, [(u[:4], w[:4]), (u[4:], w[4:])])
    assert eng.is_warm_stream(f, 4, 64, np.float32)
    assert not eng.is_warm_stream(f, 5, 64, np.float32)
