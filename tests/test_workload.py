"""Trace-driven workload generator (repro/workload/):

  * arrival processes — seeded determinism, serialization round-trip,
    inter-arrival statistics within tolerance (Poisson / bursty /
    lognormal / diurnal / uniform);
  * regime schedules — exact shift boundaries;
  * tenant churn — scheduled joins land exactly, random joins are
    seed-deterministic;
  * size distributions — per-tenant stability, model-config lookup;
  * trace compilation — same seed => identical trace file (hash
    compared), JSON round-trip equality, spec round-trip rebuilds the
    identical trace;
  * replay — scripted-clock arrivals land at the traced offsets,
    payloads are deterministic;
  * the compressed-transport classify fix (Workload.for_params).
"""
import bisect
import json

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import UpdateStore
from repro.core.compress import BLOCK, compressed_bytes
from repro.workload import (
    BurstyArrivals,
    DiurnalArrivals,
    FixedSize,
    LognormalArrivals,
    LognormalSize,
    ModelConfigSize,
    PoissonArrivals,
    Regime,
    RegimeSchedule,
    TenantChurn,
    UniformArrivals,
    Workload,
    WorkloadClass,
    WorkloadSpec,
    WorkloadTrace,
    arrival_from_dict,
    classify,
    replay_round,
    size_from_dict,
    trace_payload,
)


class ScriptedClock:
    def __init__(self):
        self.t = 0.0
        self._events = []

    def at(self, t, fn):
        bisect.insort(self._events, (t, id(fn), fn))

    def clock(self):
        return self.t

    def sleep(self, seconds):
        self.t += seconds
        while self._events and self._events[0][0] <= self.t:
            _, _, fn = self._events.pop(0)
            fn()


def _spec(rounds=10, tenants=("app0", "app1"), n=8, **kw):
    defaults = dict(
        regimes=RegimeSchedule([
            Regime("uniform", UniformArrivals(spread=0.4), 0),
            Regime("bursty", BurstyArrivals(spread=0.4, arrive_frac=0.75),
                   max(rounds // 2, 1)),
        ]),
        sizes=LognormalSize(median_dim=2000, sigma=0.4),
        churn=TenantChurn(scheduled_joins=((rounds // 2, None),)),
    )
    defaults.update(kw)
    return WorkloadSpec(tenants=tuple(tenants), n_clients=n,
                        rounds=rounds, **defaults)


# -- seeded determinism --------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_same_seed_identical_trace(seed):
    spec = _spec()
    a, b = spec.build(seed), spec.build(seed)
    assert a == b
    assert a.trace_hash() == b.trace_hash()
    assert a.trace_hash() != spec.build(seed + 1).trace_hash()


def test_same_seed_identical_trace_file(tmp_path):
    """The acceptance bar is byte-level: two builds under one seed
    write IDENTICAL trace files."""
    spec = _spec()
    p1, p2 = tmp_path / "a.json", tmp_path / "b.json"
    spec.build(7).to_json(str(p1))
    spec.build(7).to_json(str(p2))
    assert p1.read_bytes() == p2.read_bytes()


def test_trace_insensitive_to_build_order():
    """Per-(round, tenant) seed streams: a tenant's round draws do not
    depend on how many tenants came before it in the loop."""
    wide = _spec(tenants=("app0", "app1", "app2"), churn=None)
    narrow = _spec(tenants=("app2",), churn=None)
    t_wide = wide.build(3)
    t_narrow = narrow.build(3)
    for r in range(t_wide.n_rounds):
        assert t_wide.rounds[r].tenant("app2").events == \
            t_narrow.rounds[r].tenant("app2").events


# -- serialization -------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_trace_json_roundtrip_equality(seed, tmp_path_factory):
    tmp = tmp_path_factory.mktemp("trace")
    trace = _spec().build(seed)
    path = str(tmp / f"t{seed}.json")
    trace.to_json(path)
    back = WorkloadTrace.from_json(path)
    assert back == trace
    assert back.trace_hash() == trace.trace_hash()


def test_spec_roundtrip_rebuilds_identical_trace():
    """spec -> dict -> spec survives the trip well enough to rebuild
    the exact same trace (the replayability contract)."""
    spec = _spec()
    trace = spec.build(11)
    spec2 = WorkloadSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert spec2.build(11).trace_hash() == trace.trace_hash()


def test_arrival_dict_roundtrip_every_kind():
    procs = [
        UniformArrivals(spread=0.7, arrive_frac=0.9),
        PoissonArrivals(rate=12.5),
        BurstyArrivals(spread=0.5, arrive_frac=0.8, window=(0.1, 0.4)),
        LognormalArrivals(spread=1.1, sigma=0.3, drop_clients=1),
        DiurnalArrivals(period=2.0, base_rate=1.0, peak_rate=9.0),
    ]
    for p in procs:
        back = arrival_from_dict(json.loads(json.dumps(p.to_dict())))
        assert back == p
    with pytest.raises(ValueError):
        arrival_from_dict({"kind": "nope"})
    with pytest.raises(ValueError):
        arrival_from_dict({"kind": "uniform", "bogus_field": 1})


def test_size_dict_roundtrip_every_kind():
    for s in (FixedSize(dim=123), LognormalSize(median_dim=500),
              ModelConfigSize(models=("CNN4.6",), scale=500)):
        assert size_from_dict(json.loads(json.dumps(s.to_dict()))) == s
    with pytest.raises(ValueError):
        size_from_dict({"kind": "nope"})
    with pytest.raises(ValueError):
        ModelConfigSize(models=("NOT_A_MODEL",))


def test_trace_version_guard(tmp_path):
    trace = _spec(rounds=2).build(0)
    d = trace.to_dict()
    d["version"] = 999
    with pytest.raises(ValueError):
        WorkloadTrace.from_dict(d)


# -- arrival statistics --------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(rate=st.floats(2.0, 25.0), seed=st.integers(0, 1000))
def test_poisson_interarrival_mean(rate, seed):
    rng = np.random.default_rng(seed)
    offs = PoissonArrivals(rate=rate).sample(rng, 4000)
    gaps = np.diff(np.concatenate([[0.0], offs]))
    assert len(offs) == 4000
    assert np.all(np.diff(offs) >= 0)
    assert np.mean(gaps) == pytest.approx(1.0 / rate, rel=0.15)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(4, 200), frac=st.floats(0.3, 1.0),
       seed=st.integers(0, 1000))
def test_bursty_window_and_dropout(n, frac, seed):
    rng = np.random.default_rng(seed)
    proc = BurstyArrivals(spread=2.0, arrive_frac=frac,
                          window=(0.05, 0.15))
    offs = proc.sample(rng, n)
    assert len(offs) == max(int(n * frac), 1)
    assert np.all(offs >= 0.05 * 2.0) and np.all(offs <= 0.15 * 2.0)
    assert np.all(np.diff(offs) >= 0)


def test_uniform_matches_classic_schedule():
    """The exact (i+1) * spread / n offsets the benchmarks scripted
    inline before the generator existed."""
    rng = np.random.default_rng(0)
    offs = UniformArrivals(spread=1.0).sample(rng, 10)
    np.testing.assert_allclose(offs, [(i + 1) * 0.1 for i in range(10)])


def test_lognormal_drops_and_clips():
    rng = np.random.default_rng(3)
    offs = LognormalArrivals(spread=0.5, drop_clients=2).sample(rng, 12)
    assert len(offs) == 10
    assert np.all(offs >= 0.0) and np.all(offs <= 0.5)


def test_diurnal_bounded_and_rate_sensitive():
    slow = DiurnalArrivals(period=4.0, base_rate=0.5, peak_rate=2.0)
    fast = DiurnalArrivals(period=4.0, base_rate=8.0, peak_rate=64.0)
    n_slow = [len(slow.sample(np.random.default_rng(s), 64))
              for s in range(8)]
    n_fast = [len(fast.sample(np.random.default_rng(s), 64))
              for s in range(8)]
    for offs in (slow.sample(np.random.default_rng(0), 64),):
        assert np.all(offs >= 0.0) and np.all(offs < 4.0)
    assert np.mean(n_fast) > np.mean(n_slow)


def test_diurnal_phase_advances_with_round_index():
    """round_advance sweeps the window across the diurnal cycle, so
    identical rng seeds draw different arrival patterns per round."""
    proc = DiurnalArrivals(period=4.0, base_rate=0.5, peak_rate=32.0,
                           round_advance=0.5)
    a = proc.sample(np.random.default_rng(1), 64, round_index=0)
    b = proc.sample(np.random.default_rng(1), 64, round_index=1)
    assert len(a) != len(b) or not np.allclose(a, b)


# -- regime schedule -----------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(boundary=st.integers(1, 99))
def test_regime_shift_boundary_exact(boundary):
    sched = RegimeSchedule([
        Regime("before", UniformArrivals(spread=1.0), 0),
        Regime("after", BurstyArrivals(spread=1.0), boundary),
    ])
    assert sched.at(boundary - 1).name == "before"
    assert sched.at(boundary).name == "after"
    assert sched.at(boundary + 1).name == "after"
    assert sched.at(0).name == "before"


def test_regime_schedule_validation():
    with pytest.raises(ValueError):
        RegimeSchedule([])
    with pytest.raises(ValueError):
        RegimeSchedule([Regime("late", UniformArrivals(), 5)])
    with pytest.raises(ValueError):
        RegimeSchedule([Regime("a", UniformArrivals(), 0),
                        Regime("b", BurstyArrivals(), 0)])
    with pytest.raises(ValueError):
        RegimeSchedule.single(UniformArrivals()).at(-1)


def test_trace_rounds_carry_regime_labels():
    trace = _spec(rounds=6, churn=None).build(0)
    assert [rt.tenants[0].regime for rt in trace.rounds] == \
        ["uniform"] * 3 + ["bursty"] * 3


# -- churn ---------------------------------------------------------------------


def test_scheduled_churn_joins_exactly():
    churn = TenantChurn(scheduled_joins=((3, 2), (5, None)))
    active = churn.schedule(np.random.default_rng(0), 8)
    assert active[2] == []
    assert active[3] == ["churn0"]
    assert active[4] == ["churn0"]
    assert active[5] == ["churn1"]          # churn0's lifetime expired
    assert active[7] == ["churn1"]
    with pytest.raises(ValueError):
        TenantChurn(scheduled_joins=((9, None),)).schedule(
            np.random.default_rng(0), 8)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 500))
def test_random_churn_deterministic_per_seed(seed):
    churn = TenantChurn(join_rate=0.4, lifetime_rounds=5)
    a = churn.schedule(np.random.default_rng(seed), 30)
    b = churn.schedule(np.random.default_rng(seed), 30)
    assert a == b


# -- sizes ---------------------------------------------------------------------


def test_tenant_dim_stable_across_rounds():
    """A tenant's clients train ONE model: its dim is sampled once and
    held for the whole horizon."""
    trace = _spec(rounds=6).build(5)
    dims = {}
    for rt in trace.rounds:
        for tr in rt.tenants:
            dims.setdefault(tr.tenant, set()).add(tr.dim)
    assert all(len(ds) == 1 for ds in dims.values())


def test_size_distributions_sample_sanely():
    rng = np.random.default_rng(0)
    assert FixedSize(dim=777).sample(rng) == 777
    assert all(LognormalSize(median_dim=100, min_dim=64).sample(rng) >= 64
               for _ in range(50))
    from repro.configs import CNN_SUITE
    dim = ModelConfigSize(models=("CNN4.6",), scale=1000).sample(rng)
    assert dim == CNN_SUITE["CNN4.6"].num_params // 1000


# -- replay --------------------------------------------------------------------


def test_replay_lands_arrivals_at_traced_offsets():
    """On a scripted clock the store's arrival timestamps equal the
    trace offsets exactly — the deterministic substrate the adaptive
    tests stand on."""
    trace = _spec(rounds=1, churn=None).build(9)
    tr = trace.rounds[0].tenant("app0")
    clk = ScriptedClock()
    store = UpdateStore(clock=clk.clock)
    wrote = replay_round(store, tr, seed=9, clock=clk.clock,
                         sleep=clk.sleep)
    arrivals = store.arrival_times("app0")
    assert wrote == len(tr.events)
    for ev in tr.events:
        assert arrivals[ev.client_id] == pytest.approx(ev.offset,
                                                       abs=1e-12)
        u, w = store.read(ev.client_id, tenant="app0")
        assert w == pytest.approx(ev.weight)
        assert np.array_equal(
            u, trace_payload(9, "app0", ev.client_id, tr.dim))


def test_trace_payload_deterministic_and_distinct():
    a = trace_payload(1, "app0", "client00000", 128)
    b = trace_payload(1, "app0", "client00000", 128)
    assert np.array_equal(a, b)
    assert a.dtype == np.float32 and a.shape == (128,)
    assert not np.array_equal(a, trace_payload(1, "app1", "client00000",
                                               128))
    assert not np.array_equal(a, trace_payload(2, "app0", "client00000",
                                               128))


# -- compressed-transport classify fix ----------------------------------------


def test_classify_uses_real_compressed_bytes():
    """PR-6 int8 rounds move ~4x fewer bytes than fp32; classifying at
    fp32 size pushed HBM_LOCAL work onto the DISTRIBUTED path. A fleet
    whose fp32 S overflows one chip but whose compressed S fits must
    classify HBM_LOCAL."""
    num_params, n = 1_000_000, 3_500        # fp32 S = 14 GB > 12 GB cap
    dense = Workload.for_params(num_params, n)
    packed = Workload.for_params(num_params, n, compressed=True)
    assert classify(dense) is WorkloadClass.DISTRIBUTED
    assert classify(packed) is WorkloadClass.HBM_LOCAL
    # the descriptor carries the REAL wire size and the REAL param count
    assert packed.update_bytes == compressed_bytes(num_params, BLOCK)
    assert packed.num_params == num_params
    assert dense.num_params == num_params
    assert packed.total_bytes < dense.total_bytes / 3.5
