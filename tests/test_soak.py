"""Tier-1 wiring for the long-horizon soak bench (benchmarks/soak_rounds.py).

The --quick soak is the trace-harness end-to-end regression gate: a
regime-shifted multi-tenant trace replayed through both gates on one
RoundScheduler service, with a mid-soak service kill/resume through
save_controller/load_controller. The smoke asserts the full acceptance
bundle — post-resume learned-gate continuity, cross-tenant prior
borrowing for the cold-start tenant, adaptive beating static at
equal-or-better inclusion — plus trace reproducibility (same seed,
same hash) against the bench's own spec builder.
"""
import argparse
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "benchmarks"))

import soak_rounds  # noqa: E402


def _quick_args(**over):
    ns = argparse.Namespace(
        quick=True, tenants=2, n=6, p=4_000, rounds=24, spread=0.12,
        timeout=0.6, cost_bias=0.5, seed=0, restart_round=12,
        churn_round=9, trace_out=None, out=None,
    )
    for k, v in over.items():
        setattr(ns, k, v)
    return ns


def test_soak_spec_reproducible_by_seed(tmp_path):
    """Identical --seed => identical trace FILE (hash-compared), and a
    different seed diverges — the replayability contract the soak's
    BENCH numbers rest on."""
    args = _quick_args()
    spec = soak_rounds.build_spec(args)
    a, b = spec.build(args.seed), spec.build(args.seed)
    assert a.trace_hash() == b.trace_hash()
    p1, p2 = tmp_path / "a.json", tmp_path / "b.json"
    a.to_json(str(p1))
    b.to_json(str(p2))
    assert p1.read_bytes() == p2.read_bytes()
    assert spec.build(args.seed + 1).trace_hash() != a.trace_hash()


def test_soak_spec_shape():
    """The soak's trace really exercises the harness: three regime
    segments with exact boundaries and a cold-start tenant joining
    mid-horizon."""
    args = _quick_args()
    trace = soak_rounds.build_spec(args).build(args.seed)
    regimes = [rt.tenants[0].regime for rt in trace.rounds]
    assert regimes[0] == "uniform"
    assert regimes[args.rounds // 3 - 1] == "uniform"
    assert regimes[args.rounds // 3] == "bursty_dropout"
    assert regimes[2 * (args.rounds // 3)] == "heavy_tail"
    names = {tr.tenant for rt in trace.rounds for tr in rt.tenants}
    assert names == {"app0", "app1", "churn0"}
    first_churn = min(rt.index for rt in trace.rounds
                      if any(tr.tenant == "churn0" for tr in rt.tenants))
    assert first_churn == args.churn_round


def test_soak_benchmark_quick_smoke(tmp_path):
    """The --quick soak is a tier-1 gate (mirrors the concurrent
    benchmark's): the kill/resume continuity assertion, prior
    borrowing, and adaptive-beats-static must hold end to end."""
    out = tmp_path / "BENCH_soak.json"
    trace_out = tmp_path / "soak_trace.json"
    r = subprocess.run(
        [sys.executable,
         os.path.join(REPO, "benchmarks", "soak_rounds.py"),
         "--quick", "--out", str(out), "--trace-out", str(trace_out)],
        capture_output=True, text=True, timeout=280,
        env={**os.environ,
             "PYTHONPATH": os.path.join(REPO, "src")},
    )
    assert r.returncode == 0, r.stdout + r.stderr
    payload = json.loads(out.read_text())
    assert payload["acceptance"] is True, payload
    # mid-soak kill/resume: every post-resume round closed on a
    # carried-over gate, not static re-warmup
    restart = payload["restart"]
    assert restart["continuity"] is True
    assert restart["post_resume_sources"]
    assert all(s not in ("static", "cold")
               for s in restart["post_resume_sources"].values())
    assert payload["prior_borrowing"]["borrowed"] is True
    assert payload["adaptive_beats_static"] is True
    # the bench's emitted trace file matches an in-process rebuild
    from repro.workload import WorkloadTrace
    emitted = WorkloadTrace.from_json(str(trace_out))
    args = _quick_args(seed=payload["config"]["seed"])
    rebuilt = soak_rounds.build_spec(args).build(args.seed)
    assert emitted.trace_hash() == rebuilt.trace_hash()
    assert emitted.trace_hash() == payload["trace_hash"]
