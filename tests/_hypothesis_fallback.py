"""Minimal deterministic stand-in for `hypothesis` (satellite fix).

The container does not ship hypothesis, which made five test modules fail
collection. Importing this module registers lightweight `hypothesis`,
`hypothesis.strategies` and `hypothesis.extra.numpy` modules in
``sys.modules`` implementing the tiny subset this suite uses:

  given / settings / strategies.integers / floats / tuples / extra.numpy.arrays

`given` runs ``max_examples`` deterministic samples (rng seeded from the
test's qualified name), so property tests still sweep a spread of inputs
and failures reproduce exactly. conftest.py imports this only when the
real hypothesis is absent — with hypothesis installed, nothing changes.
"""
from __future__ import annotations

import functools
import inspect
import sys
import types
import zlib

import numpy as np


class Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)

    def map(self, f):
        return Strategy(lambda rng: f(self.example(rng)))


def integers(min_value, max_value):
    return Strategy(
        lambda rng: int(rng.integers(min_value, max_value + 1))
    )


def floats(min_value, max_value, width=None, **_):
    return Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def tuples(*strategies):
    return Strategy(
        lambda rng: tuple(s.example(rng) for s in strategies)
    )


def sampled_from(seq):
    seq = list(seq)
    return Strategy(lambda rng: seq[int(rng.integers(len(seq)))])


def arrays(dtype, shape, elements=None, **_):
    def draw(rng):
        shp = shape.example(rng) if isinstance(shape, Strategy) else shape
        if isinstance(shp, int):
            shp = (shp,)
        size = int(np.prod(shp)) if shp else 1
        if elements is None:
            flat = rng.normal(size=size)
        else:
            flat = np.asarray(
                [elements.example(rng) for _ in range(size)]
            )
        return flat.reshape(shp).astype(dtype)

    return Strategy(draw)


def settings(max_examples=10, deadline=None, **_):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(*args, **kw):
    assert not args, "fallback @given supports keyword strategies only"

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*wargs, **wkwargs):
            n = getattr(wrapper, "_fallback_max_examples", 10)
            rng = np.random.default_rng(
                zlib.crc32(fn.__qualname__.encode())
            )
            for _ in range(n):
                drawn = {k: s.example(rng) for k, s in kw.items()}
                fn(*wargs, **wkwargs, **drawn)

        # hide the strategy parameters from pytest's fixture resolution
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in kw
        ])
        del wrapper.__wrapped__
        return wrapper

    return deco


def install() -> None:
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    st_mod = types.ModuleType("hypothesis.strategies")
    for f in (integers, floats, tuples, sampled_from):
        setattr(st_mod, f.__name__, f)
    hyp.strategies = st_mod
    extra = types.ModuleType("hypothesis.extra")
    hnp = types.ModuleType("hypothesis.extra.numpy")
    hnp.arrays = arrays
    extra.numpy = hnp
    hyp.extra = extra
    sys.modules.update({
        "hypothesis": hyp,
        "hypothesis.strategies": st_mod,
        "hypothesis.extra": extra,
        "hypothesis.extra.numpy": hnp,
    })
