"""Quantized transport + error feedback (beyond-paper, core/compress.py)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.compress import (
    ErrorFeedbackCompressor,
    compression_ratio,
    dequantize,
    quantize,
)
from repro.core.fusion import FedAvg
from repro.core.local import LocalEngine

RNG = np.random.default_rng(21)


def test_quantize_roundtrip_error_bounded():
    v = jnp.asarray(RNG.normal(size=(5000,)).astype(np.float32))
    q, s = quantize(v)
    back = dequantize(q, s)
    # error bounded by half a quantization step per block
    err = np.abs(np.asarray(back - v))
    step = np.repeat(np.asarray(s), 2048)[: v.shape[0]]
    assert (err <= step / 2 + 1e-7).all()


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 5000), seed=st.integers(0, 99))
def test_quantize_shapes_property(n, seed):
    r = np.random.default_rng(seed)
    v = jnp.asarray(r.normal(size=(n,)).astype(np.float32) * 10)
    q, s = quantize(v)
    assert q.shape == (n,) and q.dtype == jnp.int8
    back = dequantize(q, s)
    assert back.shape == (n,)
    assert np.isfinite(np.asarray(back)).all()


def test_error_feedback_compensates():
    """Mean of EF-compressed repeated updates converges to the true mean
    (the residual carries what quantization dropped)."""
    block = 256
    ef = ErrorFeedbackCompressor(block=block)
    true = jnp.asarray(RNG.normal(size=(1024,)).astype(np.float32) * 1e-3)
    acc = np.zeros(1024, np.float64)
    T = 30
    for t in range(T):
        q, s = ef.compress(0, true)
        acc += np.asarray(dequantize(q, s, block), np.float64)
    np.testing.assert_allclose(acc / T, np.asarray(true), atol=2e-5)


def test_compressed_fedavg_close_to_exact():
    n, p = 16, 4096
    u = RNG.normal(size=(n, p)).astype(np.float32)
    w = RNG.uniform(1, 10, size=(n,)).astype(np.float32)
    ef = ErrorFeedbackCompressor()
    deq = np.stack([
        np.asarray(dequantize(*ef.compress(i, jnp.asarray(u[i]))))
        for i in range(n)
    ])
    eng = LocalEngine(strategy="jnp")
    exact = np.asarray(eng.fuse(FedAvg(), u, w))
    approx = np.asarray(eng.fuse(FedAvg(), deq, w))
    scale = np.abs(u).max()
    assert np.abs(exact - approx).max() < scale / 127  # one q-step


def test_compression_ratio():
    assert 3.9 < compression_ratio(1 << 20) <= 4.0
