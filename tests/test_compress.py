"""Quantized transport + error feedback (beyond-paper, core/compress.py)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.compress import (
    BLOCK,
    CompressedBlock,
    CompressedUpdate,
    ErrorFeedbackCompressor,
    compress_update,
    compressed_bytes,
    compression_ratio,
    dequantize,
    quantize,
)
from repro.core.fusion import FedAvg
from repro.core.local import LocalEngine
from repro.core.service import AggregationService
from repro.core.store import UpdateStore

RNG = np.random.default_rng(21)


def test_quantize_roundtrip_error_bounded():
    v = jnp.asarray(RNG.normal(size=(5000,)).astype(np.float32))
    q, s = quantize(v)
    back = dequantize(q, s)
    # error bounded by half a quantization step per block
    err = np.abs(np.asarray(back - v))
    step = np.repeat(np.asarray(s), 2048)[: v.shape[0]]
    assert (err <= step / 2 + 1e-7).all()


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 5000), seed=st.integers(0, 99))
def test_quantize_shapes_property(n, seed):
    r = np.random.default_rng(seed)
    v = jnp.asarray(r.normal(size=(n,)).astype(np.float32) * 10)
    q, s = quantize(v)
    assert q.shape == (n,) and q.dtype == jnp.int8
    back = dequantize(q, s)
    assert back.shape == (n,)
    assert np.isfinite(np.asarray(back)).all()


def test_error_feedback_compensates():
    """Mean of EF-compressed repeated updates converges to the true mean
    (the residual carries what quantization dropped)."""
    block = 256
    ef = ErrorFeedbackCompressor(block=block)
    true = jnp.asarray(RNG.normal(size=(1024,)).astype(np.float32) * 1e-3)
    acc = np.zeros(1024, np.float64)
    T = 30
    for t in range(T):
        q, s = ef.compress(0, true)
        acc += np.asarray(dequantize(q, s, block), np.float64)
    np.testing.assert_allclose(acc / T, np.asarray(true), atol=2e-5)


def test_compressed_fedavg_close_to_exact():
    n, p = 16, 4096
    u = RNG.normal(size=(n, p)).astype(np.float32)
    w = RNG.uniform(1, 10, size=(n,)).astype(np.float32)
    ef = ErrorFeedbackCompressor()
    deq = np.stack([
        np.asarray(dequantize(*ef.compress(i, jnp.asarray(u[i]))))
        for i in range(n)
    ])
    eng = LocalEngine(strategy="jnp")
    exact = np.asarray(eng.fuse(FedAvg(), u, w))
    approx = np.asarray(eng.fuse(FedAvg(), deq, w))
    scale = np.abs(u).max()
    assert np.abs(exact - approx).max() < scale / 127  # one q-step


def test_compression_ratio():
    assert 3.9 < compression_ratio(1 << 20) <= 4.0


# -- quantize contract --------------------------------------------------------


def test_quantize_all_zero_and_spike_blocks():
    """Degenerate blocks: an all-zero block must round-trip to exact
    zeros (scale floors at 1e-12, codes are 0), and a single-spike
    block must preserve the spike within half a step."""
    block = 128
    v = np.zeros(3 * block, np.float32)
    v[2 * block + 17] = 5.0          # spike in the last block only
    q, s = quantize(jnp.asarray(v), block=block)
    back = np.asarray(dequantize(q, s, block))
    assert (back[: 2 * block] == 0.0).all()
    assert abs(back[2 * block + 17] - 5.0) <= float(s[2]) / 2 + 1e-7


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 3000), seed=st.integers(0, 99))
def test_quantize_per_element_error_property(n, seed):
    """Per-element |dequant - x| <= scale/2 for every block, any length."""
    block = 256
    r = np.random.default_rng(seed)
    v = (r.normal(size=(n,)) * 10 ** r.uniform(-4, 2)).astype(np.float32)
    q, s = quantize(jnp.asarray(v), block=block)
    back = np.asarray(dequantize(q, s, block))
    step = np.repeat(np.asarray(s), block)[:n]
    assert (np.abs(back - v) <= step / 2 + 1e-6).all()


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float16])
def test_quantize_low_precision_inputs_keep_fp32_scales(dtype):
    """bf16/fp16 updates quantize without silently changing the return
    contract: int8 codes + FP32 scales, always."""
    v = jnp.asarray(RNG.normal(size=(600,)).astype(np.float32)).astype(dtype)
    q, s = quantize(v, block=128)
    assert q.dtype == jnp.int8 and s.dtype == jnp.float32
    back = np.asarray(dequantize(q, s, 128))
    err = np.abs(back - np.asarray(v, np.float32))
    assert (err <= np.repeat(np.asarray(s), 128)[:600] / 2 + 1e-2).all()


def test_compressed_bytes_counts_padding_and_scales():
    """The byte model is the padded codes + the fp32 scale vector —
    what the spool actually holds (satellite 1: the padded final block
    and the scales were previously uncounted)."""
    assert compressed_bytes(2048, 2048) == 2048 + 4
    assert compressed_bytes(2049, 2048) == 2 * 2048 + 8   # padded block
    cu = compress_update(np.ones(2049, np.float32))
    assert cu.nbytes == compressed_bytes(2049, 2048)


# -- store round-trip ---------------------------------------------------------


def test_store_roundtrips_compressed_updates(tmp_path):
    """CompressedUpdates survive write -> read and write -> iter_chunks
    on BOTH backends, without the store ever holding fp32."""
    v = RNG.normal(size=(5003,)).astype(np.float32)
    cu = compress_update(v)
    for store in (
        UpdateStore(),
        UpdateStore(backend="disk", spool_dir=str(tmp_path)),
    ):
        store.write("c0", cu, weight=2.0)
        got, w = store.read("c0")
        assert isinstance(got, CompressedUpdate) and w == 2.0
        np.testing.assert_allclose(got.dequantize(), cu.dequantize())
        n, p, dtype = store.meta()
        assert (n, p, dtype) == (1, 5003, np.dtype(np.int8))
        blocks = list(store.iter_chunks(4))
        assert len(blocks) == 1
        assert isinstance(blocks[0][0], CompressedBlock)


def test_store_quota_counts_compressed_bytes():
    """Satellite bugfix: per-tenant byte accounting charges the real
    on-spool compressed size (codes + scales), not the logical fp32
    size."""
    p = 4096
    cu = compress_update(np.ones(p, np.float32))
    store = UpdateStore(replication=1)
    store.write("c0", cu, tenant="appA")
    assert store.tenant_bytes("appA") == cu.nbytes   # ~p + 8, NOT 4p
    assert store.tenant_bytes("appA") < p * 4 // 3   # ~4x under fp32
    # a quota sized for compressed payloads admits them
    store.set_quota("appB", max_bytes=3 * cu.nbytes, policy="reject")
    for i in range(3):
        store.write(f"c{i}", compress_update(np.ones(p, np.float32)),
                    tenant="appB")
    assert store.tenant_bytes("appB") == 3 * cu.nbytes


def test_mixed_round_through_engine():
    """One stream may mix compressed and dense rows (a straggler that
    skipped quantization): per-kind steps, ONE accumulator."""
    n, p = 9, 5000
    u = RNG.normal(size=(n, p)).astype(np.float32)
    store = UpdateStore()
    for i in range(n - 2):
        store.write(f"c{i}", compress_update(u[i]))
    store.write("c7", u[7])
    store.write("c8", u[8])
    eng = LocalEngine(strategy="jnp")
    fused, rep = eng.fuse_stream(FedAvg(), store.iter_chunks(4),
                                 chunk_rows=4)
    exact = u.mean(0)
    assert np.abs(np.asarray(fused) - exact).max() < np.abs(u).max() / 127
    assert rep.ingest_bytes == 7 * compressed_bytes(p) + 2 * p * 4


# -- service-level quantized transport ----------------------------------------


def test_service_compressed_round_and_ingest_bytes():
    """A compressed round streams codes+scales end to end; RoundReport
    counts the real ingest bytes at < 0.3x the dense round's (satellite
    5's CI assertion, equal n and P)."""
    n, p = 12, 100_000
    u = RNG.normal(size=(n, p)).astype(np.float32)
    exact = u.mean(0)

    store_d = UpdateStore()
    svc_d = AggregationService(local_strategy="jnp", store=store_d)
    for i in range(n):
        store_d.write(f"c{i}", u[i])
    fused_d, rep_d = svc_d.aggregate(from_store=True, expected_clients=n)
    assert rep_d.bytes_ingested == n * p * 4

    store_c = UpdateStore()
    svc_c = AggregationService(local_strategy="jnp", store=store_c,
                               compress=True)
    for i in range(n):
        store_c.write(f"c{i}", svc_c.compress_update(f"c{i}", u[i]))
    fused_c, rep_c = svc_c.aggregate(from_store=True, expected_clients=n)
    assert rep_c.streamed
    assert rep_c.bytes_ingested == n * compressed_bytes(p)
    assert rep_c.bytes_ingested < 0.3 * rep_d.bytes_ingested
    assert np.abs(np.asarray(fused_c) - exact).max() < np.abs(u).max() / 127
    np.testing.assert_allclose(np.asarray(fused_d), exact, rtol=1e-5,
                               atol=1e-5)


def test_service_compress_update_requires_flag():
    svc = AggregationService()
    with pytest.raises(ValueError):
        svc.compress_update("c0", np.ones(10, np.float32))


def test_ef_multi_round_convergence_matches_fedavg():
    """Satellite 3: with error feedback, the multi-round fused mean of
    compressed rounds tracks uncompressed FedAvg — per-round residuals
    carry instead of accumulating."""
    n, p, T = 6, 4096, 12
    svc = AggregationService(compress=True)
    rng = np.random.default_rng(4)
    sum_c = np.zeros(p, np.float64)
    sum_x = np.zeros(p, np.float64)
    for t in range(T):
        u = rng.normal(size=(n, p)).astype(np.float32) * 1e-2
        store = UpdateStore()
        svc.store = store
        for i in range(n):
            store.write(f"c{i}", svc.compress_update(f"c{i}", u[i]))
        fused, _ = svc.aggregate(from_store=True, expected_clients=n)
        sum_c += np.asarray(fused, np.float64)
        sum_x += u.mean(0)
        store.clear()
    # cumulative error stays at ONE round's quantization step, not T's
    one_step = 1e-2 * 5 / 127
    assert np.abs(sum_c - sum_x).max() < 2 * one_step
